//! Long-lived pinned worker pool + chunked compensated dot: the compute
//! side of the persistent engine.
//!
//! Workers are spawned **once** (pinned round-robin to CPUs, like the
//! paper's likwid-pin runs) and park in a condvar between jobs — the
//! request path never calls `thread::spawn`. A dot is partitioned into
//! cache-line-aligned chunks (boundaries at 64-byte multiples of the
//! element type), each chunk runs a host SIMD kernel from the registry,
//! and the per-chunk partials are merged with the existing compensated
//! (Neumaier) fold.
//!
//! Error bound: each chunk result is a Kahan-compensated dot of its
//! sub-vectors (the registry kernels fold their per-lane compensation
//! terms internally before returning, so a chunk's pending `comp` is
//! already absorbed into its `sum`); the cross-chunk merge is itself
//! compensated, adding one protected rounding per chunk. The parallel
//! result therefore keeps the sequential Kahan bound
//! `O(u)·Σ|aᵢbᵢ|` independent of chunk count — property-tested in
//! `rust/tests/test_engine.rs` against `exact_dot_*` on Ogita–Rump–Oishi
//! ill-conditioned inputs.
//!
//! Determinism: chunk boundaries depend only on `(n, chunks)` and the
//! merge folds partials in chunk order, so a given engine configuration is
//! bit-reproducible run to run regardless of worker scheduling.

use super::pool::PooledSlice;
use crate::bench::kernels::{compensated_fold_f32, compensated_fold_f64};
use crate::bench::threads::pin_to_cpu;
use crate::util::faults::{self, FaultAction, Heartbeat};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// A unit of work executed on a pool worker.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

struct WorkerShared {
    state: Mutex<QueueState>,
    cv: Condvar,
    /// 0 = idle, else the `faults::now_us` timestamp at which the current
    /// job started — the supervision sweep's wedge signal
    hb: Heartbeat,
    /// bumped by [`WorkerPool::supervise`] when it replaces this slot's
    /// thread; a thread whose captured epoch falls behind exits at its
    /// next loop top (after finishing — or never finishing — its current
    /// job), so a wedged thread can never race its replacement's queue
    epoch: AtomicUsize,
}

/// One worker slot: the queue (and its thread) survive respawns — a
/// replacement thread runs `worker_main` over the *same* shared queue,
/// so queued jobs are never lost to a worker death.
struct WorkerSlot {
    shared: Arc<WorkerShared>,
    /// explicit pin target (`new_on` CPU list), re-applied on respawn
    target: Option<usize>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Persistent worker pool: spawn once, park between jobs, join on drop.
/// Self-healing: [`WorkerPool::supervise`] detects dead (join-handle
/// finished) and wedged (stale heartbeat) workers and respawns them
/// re-pinned, counted in [`WorkerPool::respawns`].
pub struct WorkerPool {
    workers: Vec<WorkerSlot>,
    next: AtomicUsize,
    pin_failures: Arc<AtomicUsize>,
    respawn_pin_failures: Arc<AtomicUsize>,
    respawns: AtomicUsize,
}

fn worker_main(shared: &WorkerShared, index: usize, my_epoch: usize) {
    loop {
        // replaced by the supervisor (wedge respawn): exit so the queue
        // has exactly one live owner again
        if shared.epoch.load(Ordering::Relaxed) != my_epoch {
            return;
        }
        let job = {
            let mut g = shared.state.lock().unwrap();
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break Some(j);
                }
                if g.closed {
                    break None;
                }
                g = shared.cv.wait(g).unwrap();
            }
        };
        match job {
            // A panicking job must not kill the worker: a dead worker would
            // leave its queue draining to nobody, so any later dot routed to
            // it would block its caller forever. The unwind is caught here
            // (jobs that need the payload, like `parallel_dot_*`, also wrap
            // their own body to report the panic explicitly).
            Some(j) => {
                shared.hb.busy();
                match faults::check("worker", index) {
                    // injected thread death: the popped job is dropped, so
                    // its reply channel disconnects and the chunk collector
                    // sees a clean "worker died" — never a fabricated
                    // partial. Queued jobs stay for the respawned thread.
                    Some(FaultAction::Die) => {
                        shared.hb.idle();
                        drop(j);
                        return;
                    }
                    // injected thread-killing panic (unlike a *job* panic,
                    // which is caught below): unwinds out of the thread,
                    // dropping the job on the way
                    Some(FaultAction::Panic) => {
                        panic!("faultinject: worker {index} killed")
                    }
                    Some(FaultAction::Stall(us)) => {
                        std::thread::sleep(std::time::Duration::from_micros(us));
                    }
                    None => {}
                }
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j));
                shared.hb.idle();
            }
            None => return,
        }
    }
}

/// Spawn one worker thread for slot `index`: pin (exact target, or the
/// `index`-th allowed CPU), count a failure into `failures`, then serve
/// the slot's queue until closed or replaced.
fn spawn_worker(
    index: usize,
    shared: Arc<WorkerShared>,
    target: Option<usize>,
    failures: Arc<AtomicUsize>,
    epoch: usize,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("engine-worker-{index}"))
        .spawn(move || {
            let pinned = match target {
                Some(cpu) => crate::bench::threads::pin_to_exact_cpu(cpu),
                None => pin_to_cpu(index),
            };
            if !pinned {
                failures.fetch_add(1, Ordering::Relaxed);
            }
            worker_main(&shared, index, epoch);
        })
        .expect("spawn engine worker")
}

impl WorkerPool {
    /// Spawn `threads` workers (at least one), worker `i` pinned to the
    /// `i`-th CPU of the process's **allowed** CPU set, wrapping over that
    /// set (see [`pin_to_cpu`]). Pinning is best effort: failures are
    /// counted and visible via [`WorkerPool::pin_failures`].
    pub fn new(threads: usize) -> WorkerPool {
        Self::new_on(threads, &[])
    }

    /// Spawn `threads` workers (at least one) pinned round-robin onto the
    /// explicit CPU list `cpus` (worker `i` → `cpus[i % cpus.len()]`,
    /// exact ids, no wrapping) — this is how a NUMA shard keeps its
    /// workers inside its own domain. An empty `cpus` falls back to the
    /// process's allowed CPU set (worker `i` → `i`-th allowed CPU,
    /// wrapped).
    pub fn new_on(threads: usize, cpus: &[usize]) -> WorkerPool {
        let threads = threads.max(1);
        let pin_failures = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let shared = Arc::new(WorkerShared {
                state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
                cv: Condvar::new(),
                hb: Heartbeat::new(),
                epoch: AtomicUsize::new(0),
            });
            let target = if cpus.is_empty() { None } else { Some(cpus[i % cpus.len()]) };
            let join =
                spawn_worker(i, Arc::clone(&shared), target, Arc::clone(&pin_failures), 0);
            workers.push(WorkerSlot { shared, target, join: Mutex::new(Some(join)) });
        }
        WorkerPool {
            workers,
            next: AtomicUsize::new(0),
            pin_failures,
            respawn_pin_failures: Arc::new(AtomicUsize::new(0)),
            respawns: AtomicUsize::new(0),
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Workers whose affinity call failed (best-effort pinning signal;
    /// 0 on a healthy Linux host, `size()` on platforms without pinning).
    pub fn pin_failures(&self) -> usize {
        self.pin_failures.load(Ordering::Relaxed)
    }

    /// Workers respawned by [`WorkerPool::supervise`] after a death or
    /// wedge — the self-healing counter behind `EngineStats::respawns`.
    pub fn respawns(&self) -> usize {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Respawned workers whose re-pin failed (counted separately from
    /// first-spawn [`WorkerPool::pin_failures`]: a respawn that lands
    /// unpinned is a *degraded* recovery, not a healthy one).
    pub fn respawn_pin_failures(&self) -> usize {
        self.respawn_pin_failures.load(Ordering::Relaxed)
    }

    /// One supervision sweep: detect dead workers (thread finished while
    /// the pool is open — a panicking or injected-death thread) and
    /// wedged workers (heartbeat busy for more than `wedge_us`
    /// microseconds; 0 disables wedge detection), and respawn each
    /// re-pinned to its original target. The replacement serves the SAME
    /// queue, so jobs queued behind a dead worker are served, not lost;
    /// the job the dead worker held was dropped by its unwind/exit, so
    /// its reply channel reports a clean "worker died" to the chunk
    /// collector — a respawn never fabricates a partial. Returns the
    /// number of workers respawned in this sweep.
    pub fn supervise(&self, wedge_us: u64) -> usize {
        let mut respawned = 0usize;
        for (i, w) in self.workers.iter().enumerate() {
            if w.shared.state.lock().unwrap_or_else(|p| p.into_inner()).closed {
                continue;
            }
            let mut join = w.join.lock().unwrap_or_else(|p| p.into_inner());
            let dead = join.as_ref().map_or(true, |h| h.is_finished());
            if dead || w.shared.hb.wedged(wedge_us) {
                if dead {
                    // reap the dead thread; a *wedged* thread instead gets
                    // its epoch bumped (it exits at its next loop top) and
                    // its old handle dropped — joining it here would block
                    // the sweep behind the very stall it is healing
                    if let Some(h) = join.take() {
                        let _ = h.join();
                    }
                }
                let epoch = w.shared.epoch.fetch_add(1, Ordering::Relaxed) + 1;
                w.shared.hb.idle();
                *join = Some(spawn_worker(
                    i,
                    Arc::clone(&w.shared),
                    w.target,
                    Arc::clone(&self.respawn_pin_failures),
                    epoch,
                ));
                self.respawns.fetch_add(1, Ordering::Relaxed);
                respawned += 1;
            }
        }
        respawned
    }

    /// Enqueue `job` on worker `worker % size()`.
    pub fn submit_to(&self, worker: usize, job: Job) {
        let w = &self.workers[worker % self.workers.len()];
        let mut g = w.shared.state.lock().unwrap();
        assert!(!g.closed, "submit to closed worker pool");
        g.jobs.push_back(job);
        w.shared.cv.notify_one();
    }

    /// Enqueue `job` on the next worker round-robin.
    pub fn submit(&self, job: Job) {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        self.submit_to(i, job);
    }

    /// Starting worker for a governed job that will occupy `span`
    /// consecutive workers (mod `size()`). Advancing the shared cursor by
    /// the whole span rotates concurrent capped requests onto *disjoint*
    /// subsets, so the workers a saturation cap frees genuinely serve
    /// other requests instead of idling behind the same queues. A
    /// full-width span always starts at 0, which keeps the uncapped
    /// path's chunk→worker assignment exactly what it was.
    pub fn subset_start(&self, span: usize) -> usize {
        if span >= self.workers.len() {
            0
        } else {
            self.next.fetch_add(span, Ordering::Relaxed) % self.workers.len()
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let mut g = w.shared.state.lock().unwrap_or_else(|p| p.into_inner());
            g.closed = true;
            w.shared.cv.notify_all();
        }
        for w in &mut self.workers {
            let join = w.join.lock().unwrap_or_else(|p| p.into_inner()).take();
            if let Some(join) = join {
                let _ = join.join();
            }
        }
    }
}

/// Split `n` elements into up to `chunks` ranges whose boundaries fall on
/// cache-line multiples of the element type (`elems_per_cl` = 16 for f32,
/// 8 for f64), balanced to within one cache line: whole cache lines are
/// dealt `⌊lines/chunks⌋` each with the `lines % chunks` leftovers going
/// one apiece to the leading chunks, and only the final range absorbs the
/// sub-line tail. (The old code gave the entire remainder to the last
/// chunk — `n=1000, chunks=7` produced six chunks of 128 and one of 232,
/// a ~1.8× straggler that stretched the parallel critical path.)
/// `chunks` is capped so every range holds at least one cache line, so
/// tiny `n` degenerates to a single chunk.
pub fn chunk_ranges(n: usize, chunks: usize, elems_per_cl: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let lines = n / elems_per_cl;
    let chunks = chunks.max(1).min(lines.max(1));
    if chunks == 1 {
        return vec![(0, n)];
    }
    let base = lines / chunks;
    let extra = lines % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len_lines = base + usize::from(i < extra);
        let end = if i == chunks - 1 { n } else { start + len_lines * elems_per_cl };
        out.push((start, end));
        start = end;
    }
    out
}

/// Render a panic payload for cross-thread propagation.
pub(crate) fn panic_message(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Drain per-chunk outcomes and re-assemble them in chunk order. A chunk
/// that panicked (or never reported — a worker died, which the pool's
/// unwind guard should make impossible) propagates as a panic on the
/// caller's thread: the old code fabricated a silent `0.0` partial for a
/// lost chunk and returned a wrong value.
pub(crate) fn collect_partials<T: Copy>(
    rx: mpsc::Receiver<(usize, Result<T, String>)>,
    count: usize,
    what: &str,
) -> Vec<T> {
    let mut slots: Vec<Option<Result<T, String>>> = (0..count).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    let mut out = Vec::with_capacity(count);
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(v)) => out.push(v),
            Some(Err(msg)) => panic!("{what}: chunk {i} panicked: {msg}"),
            None => panic!("{what}: chunk {i} reported no partial (worker died)"),
        }
    }
    out
}

macro_rules! parallel_dot_impl {
    ($name:ident, $capped:ident, $ty:ty, $elems_per_cl:expr, $fold:ident) => {
        /// Chunked-parallel compensated dot over pooled aligned streams:
        /// each chunk runs `f` on a worker, partials merge with the
        /// compensated fold in chunk order (deterministic).
        ///
        /// `max_workers` is the ECM governance cap: the chunks occupy at
        /// most that many workers, submitted round-robin over a rotated
        /// subset ([`WorkerPool::subset_start`]) so concurrent capped
        /// requests spread across disjoint subsets. The cap changes
        /// *concurrency only* — chunk geometry depends on `(n, chunks)`
        /// alone and partials always merge in chunk order, so any
        /// `max_workers` produces bit-identical results.
        ///
        /// Panic policy: each chunk job reports an explicit outcome, so a
        /// panicking kernel re-panics *here* with the original payload
        /// message instead of leaving a silent `0.0` partial in the merge,
        /// and the pool's workers survive for the next request.
        pub fn $capped(
            pool: &WorkerPool,
            f: fn(&[$ty], &[$ty]) -> $ty,
            a: &Arc<PooledSlice<$ty>>,
            b: &Arc<PooledSlice<$ty>>,
            chunks: usize,
            max_workers: usize,
        ) -> $ty {
            let n = a.len().min(b.len());
            let ranges = chunk_ranges(n, chunks, $elems_per_cl);
            if ranges.len() <= 1 {
                return f(&a.as_slice()[..n], &b.as_slice()[..n]);
            }
            let slots = max_workers.max(1).min(pool.size());
            let base = pool.subset_start(slots);
            let (tx, rx) = mpsc::channel::<(usize, Result<$ty, String>)>();
            for (i, &(lo, hi)) in ranges.iter().enumerate() {
                let a = Arc::clone(a);
                let b = Arc::clone(b);
                let tx = tx.clone();
                pool.submit_to(base + (i % slots), Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        // "chunk" faults stay *inside* the unwind guard: an
                        // injected chunk failure is a caught per-chunk error
                        // (a stall is just a slow chunk), never a dead worker
                        if faults::act(faults::check("chunk", i)) {
                            panic!("faultinject: chunk {i} killed");
                        }
                        f(&a.as_slice()[lo..hi], &b.as_slice()[lo..hi])
                    }));
                    let _ = tx.send((i, r.map_err(panic_message)));
                }));
            }
            drop(tx);
            // collect in chunk order for a deterministic merge; a panicked
            // or missing chunk propagates instead of folding a zero
            let sums = collect_partials(rx, ranges.len(), stringify!($name));
            // per-chunk compensations are already folded into each chunk's
            // sum by the kernel; the merge only needs its own compensation
            let comps = vec![0.0 as $ty; sums.len()];
            $fold(&sums, &comps)
        }

        /// Uncapped convenience form: every chunk gets its own worker
        /// (the pre-governance behaviour, chunk `i` on worker `i`).
        pub fn $name(
            pool: &WorkerPool,
            f: fn(&[$ty], &[$ty]) -> $ty,
            a: &Arc<PooledSlice<$ty>>,
            b: &Arc<PooledSlice<$ty>>,
            chunks: usize,
        ) -> $ty {
            $capped(pool, f, a, b, chunks, pool.size())
        }
    };
}

parallel_dot_impl!(parallel_dot_f32, parallel_dot_capped_f32, f32, 16, compensated_fold_f32);
parallel_dot_impl!(parallel_dot_f64, parallel_dot_capped_f64, f64, 8, compensated_fold_f64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::bench::kernels::scalar;
    use crate::engine::pool::BufferPool;
    use crate::util::Rng;

    #[test]
    fn chunk_ranges_cover_and_align() {
        for (n, chunks) in [
            (0usize, 4usize),
            (5, 4),
            (64, 3),
            (1000, 7),
            (4096, 4),
            (100, 200),
            (999_983, 13),
            (1 << 20, 64),
        ] {
            let r = chunk_ranges(n, chunks, 16);
            if n == 0 {
                assert!(r.is_empty());
                continue;
            }
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(lo, hi) in &r[..r.len().saturating_sub(1)] {
                assert_eq!(lo % 16, 0, "n={n} chunks={chunks}");
                assert!(hi > lo);
            }
            // balance: the remainder is distributed in cache-line quanta,
            // so max and min chunk size stay within two cache lines
            let max = r.iter().map(|&(lo, hi)| hi - lo).max().unwrap();
            let min = r.iter().map(|&(lo, hi)| hi - lo).min().unwrap();
            assert!(
                max - min <= 2 * 16,
                "n={n} chunks={chunks}: chunk sizes {min}..{max} differ by more than 2 cache lines"
            );
        }
        // the headline imbalance case from the old code: n=1000, chunks=7
        // used to produce six chunks of 128 and one straggler of 232
        let r = chunk_ranges(1000, 7, 16);
        assert_eq!(r.len(), 7);
        let max = r.iter().map(|&(lo, hi)| hi - lo).max().unwrap();
        assert!(max <= 144, "straggler chunk is back: {r:?}");
    }

    #[test]
    fn panicking_job_does_not_kill_worker() {
        let pool = WorkerPool::new(2);
        // a job that panics must neither poison the pool nor kill the
        // worker thread its queue belongs to
        for round in 0..2 {
            pool.submit_to(0, Box::new(|| panic!("injected job panic")));
            let (tx, rx) = mpsc::channel();
            for w in 0..2 {
                let tx = tx.clone();
                pool.submit_to(w, Box::new(move || {
                    let _ = tx.send(w);
                }));
            }
            drop(tx);
            let mut got: Vec<usize> = rx.iter().collect();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1], "round {round}: worker died after a panicking job");
        }
    }

    #[test]
    fn pool_runs_jobs_and_survives_reuse() {
        let pool = WorkerPool::new(3);
        for round in 0..3 {
            let (tx, rx) = mpsc::channel();
            for i in 0..10usize {
                let tx = tx.clone();
                pool.submit(Box::new(move || {
                    let _ = tx.send(i);
                }));
            }
            drop(tx);
            let mut got: Vec<usize> = rx.iter().collect();
            got.sort_unstable();
            assert_eq!(got, (0..10).collect::<Vec<_>>(), "round {round}");
        }
    }

    #[test]
    fn parallel_dot_matches_exact_across_chunk_counts() {
        let pool = WorkerPool::new(2);
        let bufs = BufferPool::new();
        let mut rng = Rng::new(77);
        let n = 10_000;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        for chunks in [1usize, 2, 3, 5, 8, 17] {
            let got =
                parallel_dot_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, chunks) as f64;
            let rel = (got - exact).abs() / scale;
            assert!(rel < 1e-6, "chunks={chunks}: rel={rel:e}");
        }
    }

    #[test]
    fn parallel_dot_is_deterministic() {
        let pool = WorkerPool::new(4);
        let bufs = BufferPool::new();
        let mut rng = Rng::new(5);
        let av = rng.normal_f32_vec(7777);
        let bv = rng.normal_f32_vec(7777);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let first = parallel_dot_f32(&pool, scalar::kahan_seq_f32, &a, &b, 4);
        for _ in 0..5 {
            let again = parallel_dot_f32(&pool, scalar::kahan_seq_f32, &a, &b, 4);
            assert_eq!(first.to_bits(), again.to_bits(), "merge must be bit-stable");
        }
    }

    /// The governance cap changes which workers run the chunks, never the
    /// chunk geometry or merge order — every cap must be bit-identical to
    /// the uncapped reduction.
    #[test]
    fn capped_dot_bit_identical_to_uncapped() {
        let pool = WorkerPool::new(4);
        let bufs = BufferPool::new();
        let mut rng = Rng::new(31);
        let av = rng.normal_f32_vec(50_000);
        let bv = rng.normal_f32_vec(50_000);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let chunks = 8;
        let uncapped = parallel_dot_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, chunks);
        for cap in [1usize, 2, 3, 4, 7, usize::MAX] {
            let capped =
                parallel_dot_capped_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, chunks, cap);
            assert_eq!(
                uncapped.to_bits(),
                capped.to_bits(),
                "cap={cap}: governance changed bits"
            );
        }
    }

    /// Self-healing sweep: a worker stalled past the wedge threshold is
    /// replaced (same queue, so nothing queued is lost), the replacement
    /// serves new jobs while the old thread is still stuck, the old
    /// thread exits at its epoch check once its job ends, and a healthy
    /// pool respawns nothing.
    #[test]
    fn supervise_replaces_wedged_worker_and_queue_survives() {
        use std::time::Duration;
        let pool = WorkerPool::new(2);
        let (wtx, wrx) = mpsc::channel();
        pool.submit_to(0, Box::new(move || {
            std::thread::sleep(Duration::from_millis(300));
            let _ = wtx.send(());
        }));
        // let the worker enter the stall, then sweep with a 10 ms wedge
        // threshold — exactly one respawn
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(pool.supervise(10_000), 1);
        assert_eq!(pool.respawns(), 1);
        // the replacement owns the same queue: new jobs are served while
        // the wedged thread is still inside its stall
        let (tx, rx) = mpsc::channel();
        pool.submit_to(0, Box::new(move || {
            let _ = tx.send(7u32);
        }));
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(30)).expect("replacement must serve"),
            7
        );
        // the wedged thread finishes its job and exits via the epoch check
        assert!(wrx.recv_timeout(Duration::from_secs(30)).is_ok());
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(pool.supervise(10_000), 0, "healthy pool must not respawn");
        assert!(pool.respawn_pin_failures() <= 1);
    }

    #[test]
    fn f64_parallel_dot_matches() {
        use crate::accuracy::exact::exact_dot_f64;
        let pool = WorkerPool::new(2);
        let bufs = BufferPool::new();
        let mut rng = Rng::new(9);
        let av = rng.normal_f64_vec(4097);
        let bv = rng.normal_f64_vec(4097);
        let exact = exact_dot_f64(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1e-300);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f64(&pool, scalar::kahan_unrolled_f64, &a, &b, 3);
        assert!((got - exact).abs() / scale < 1e-14);
    }
}
