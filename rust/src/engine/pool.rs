//! 64-byte-aligned recycling buffer pool: the allocation-free admission
//! path of the persistent dot engine.
//!
//! Every stream the engine touches lives in a cache-line-aligned buffer
//! (`std::alloc` with an explicit `Layout`), so SIMD kernels never straddle
//! a line at the block head and chunk boundaries can be cut exactly on
//! 64-byte multiples. Buffers are bucketed by power-of-two capacity and
//! recycled on drop: after warm-up a steady stream of same-sized requests
//! performs **zero** heap allocation and touches only already-faulted pages
//! — the difference `bench_engine` measures against the old
//! fresh-`Vec`-per-call path.

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::collections::HashMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache-line alignment for every pooled buffer.
pub const ALIGN: usize = 64;

/// Max recycled buffers kept per size bucket; beyond this, returns free.
const MAX_PER_BUCKET: usize = 8;

/// One raw 64-byte-aligned allocation (capacity in bytes, always a
/// power-of-two bucket size).
struct RawBuf {
    ptr: std::ptr::NonNull<u8>,
    cap_bytes: usize,
}

// The pointer is uniquely owned by the RawBuf; moving it across threads is
// safe (it is only ever dereferenced through a PooledSlice).
unsafe impl Send for RawBuf {}

impl RawBuf {
    fn new(cap_bytes: usize) -> Self {
        let layout = Layout::from_size_align(cap_bytes, ALIGN).expect("pool layout");
        let ptr = unsafe { alloc(layout) };
        let ptr = match std::ptr::NonNull::new(ptr) {
            Some(p) => p,
            None => handle_alloc_error(layout),
        };
        RawBuf { ptr, cap_bytes }
    }
}

impl Drop for RawBuf {
    fn drop(&mut self) {
        let layout = Layout::from_size_align(self.cap_bytes, ALIGN).expect("pool layout");
        unsafe { dealloc(self.ptr.as_ptr(), layout) }
    }
}

/// Pool counters (all monotonically increasing).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// acquisitions served from a recycled buffer
    pub hits: u64,
    /// acquisitions that had to allocate
    pub misses: u64,
    /// buffers handed back to the pool
    pub returned: u64,
}

/// Thread-safe recycling pool of 64-byte-aligned buffers.
///
/// Created behind an `Arc` because every [`PooledSlice`] keeps a handle to
/// return its buffer on drop.
pub struct BufferPool {
    shelves: Mutex<HashMap<usize, Vec<RawBuf>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    returned: AtomicU64,
}

impl BufferPool {
    pub fn new() -> Arc<BufferPool> {
        Arc::new(BufferPool {
            shelves: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            returned: AtomicU64::new(0),
        })
    }

    /// Bucket a byte count to its power-of-two shelf size.
    fn bucket(bytes: usize) -> usize {
        bytes.max(ALIGN).next_power_of_two()
    }

    fn acquire_raw(&self, bytes: usize) -> RawBuf {
        let b = Self::bucket(bytes);
        if let Some(raw) = self.shelves.lock().unwrap().get_mut(&b).and_then(Vec::pop) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return raw;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        RawBuf::new(b)
    }

    fn release(&self, raw: RawBuf) {
        self.returned.fetch_add(1, Ordering::Relaxed);
        let mut shelves = self.shelves.lock().unwrap();
        let shelf = shelves.entry(raw.cap_bytes).or_default();
        if shelf.len() < MAX_PER_BUCKET {
            shelf.push(raw);
        }
        // else: raw drops here and the memory is freed
    }

    /// Copy `src` into a pooled aligned buffer (the engine's single
    /// admission copy — it buys alignment plus warm, recycled pages).
    pub fn admit<T: Copy>(self: &Arc<Self>, src: &[T]) -> PooledSlice<T> {
        debug_assert!(std::mem::align_of::<T>() <= ALIGN);
        let bytes = std::mem::size_of_val(src);
        let raw = self.acquire_raw(bytes);
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr() as *const u8, raw.ptr.as_ptr(), bytes);
        }
        PooledSlice { raw: Some(raw), len: src.len(), pool: Arc::clone(self), _elem: PhantomData }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returned: self.returned.load(Ordering::Relaxed),
        }
    }

    /// Number of buffers currently shelved (for tests/introspection).
    pub fn idle_buffers(&self) -> usize {
        self.shelves.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// A length-`len` typed view of a pooled aligned buffer. Returns the buffer
/// to its pool on drop.
pub struct PooledSlice<T: Copy> {
    raw: Option<RawBuf>,
    len: usize,
    pool: Arc<BufferPool>,
    _elem: PhantomData<T>,
}

// Safe: the underlying buffer is uniquely owned, T is plain data, and
// shared access only ever reads through `as_slice`.
unsafe impl<T: Copy + Send> Send for PooledSlice<T> {}
unsafe impl<T: Copy + Send + Sync> Sync for PooledSlice<T> {}

impl<T: Copy> PooledSlice<T> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[T] {
        let raw = self.raw.as_ref().expect("live PooledSlice");
        unsafe { std::slice::from_raw_parts(raw.ptr.as_ptr() as *const T, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        let raw = self.raw.as_ref().expect("live PooledSlice");
        unsafe { std::slice::from_raw_parts_mut(raw.ptr.as_ptr() as *mut T, self.len) }
    }

    /// The buffer's start address (for alignment assertions).
    pub fn addr(&self) -> usize {
        self.raw.as_ref().expect("live PooledSlice").ptr.as_ptr() as usize
    }
}

impl<T: Copy> std::ops::Deref for PooledSlice<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy> Drop for PooledSlice<T> {
    fn drop(&mut self) {
        if let Some(raw) = self.raw.take() {
            self.pool.release(raw);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_cache_line_aligned() {
        let pool = BufferPool::new();
        for n in [1usize, 7, 64, 1000, 65_536] {
            let buf = pool.admit(&vec![1.0f32; n]);
            assert_eq!(buf.addr() % ALIGN, 0, "n={n}");
            assert_eq!(buf.len(), n);
        }
    }

    #[test]
    fn admit_preserves_contents() {
        let pool = BufferPool::new();
        let src: Vec<f64> = (0..1234).map(|i| i as f64 * 0.5).collect();
        let buf = pool.admit(&src);
        assert_eq!(buf.as_slice(), &src[..]);
    }

    #[test]
    fn steady_state_recycles_instead_of_allocating() {
        let pool = BufferPool::new();
        let src = vec![0.0f32; 10_000];
        for _ in 0..5 {
            let a = pool.admit(&src);
            let b = pool.admit(&src);
            drop((a, b));
        }
        let s = pool.stats();
        // first round: 2 misses; the remaining 4 rounds: hits only
        assert_eq!(s.misses, 2, "{s:?}");
        assert_eq!(s.hits, 8, "{s:?}");
        assert_eq!(s.returned, 10, "{s:?}");
    }

    #[test]
    fn shelf_is_bounded() {
        let pool = BufferPool::new();
        let src = vec![0.0f32; 100];
        let bufs: Vec<_> = (0..2 * MAX_PER_BUCKET).map(|_| pool.admit(&src)).collect();
        drop(bufs);
        assert!(pool.idle_buffers() <= MAX_PER_BUCKET);
    }

    #[test]
    fn different_sizes_use_different_shelves() {
        let pool = BufferPool::new();
        let a = pool.admit(&vec![0.0f32; 10]); // 40 B -> 64 B bucket
        let b = pool.admit(&vec![0.0f32; 1000]); // 4000 B -> 4096 B bucket
        drop((a, b));
        // re-acquiring each size must hit its own shelf
        let _a = pool.admit(&vec![0.0f32; 16]);
        let _b = pool.admit(&vec![0.0f32; 900]);
        assert_eq!(pool.stats().hits, 2);
    }
}
