//! The unified request planner: every route / batch / split threshold in
//! the serving stack, compiled in ONE pure, side-effect-free layer.
//!
//! The paper's central claim is that Kahan costs nothing *if the right
//! low-level decisions are made*. This stack makes those decisions in
//! several places — inline vs chunked-parallel inside a shard engine,
//! route-to-one-shard vs split-across-all in the sharded tier, fuse vs
//! serial-loop inside a batch, wait vs serve-now in a service lane — and
//! Hofmann et al.'s follow-ups (CPE 2016; the four-generation study) show
//! every one of those thresholds is machine-dependent. So they live here,
//! in one calibrated, testable [`PlanPolicy`], and every execution layer
//! *consumes* a compiled [`DotPlan`] instead of re-deriving the decision
//! from scattered constants. The bit-identity and Kahan-bound invariants
//! below are therefore enforced at one choke point and property-tested
//! against the planner directly (`rust/tests/test_plan.rs`).
//!
//! Everything in this module is a pure function of its inputs: no
//! counters, no I/O, no engine handles. (The only cached lookup is
//! [`SizeClass::of`], which classifies against the host cache hierarchy
//! detected once per process — deterministic for the life of the process.)
//! Calibration data enters through an explicit [`DispatchTable`] argument
//! where a decision needs it, so tests can drive the planner with any
//! table.
//!
//! # Accuracy tiers
//!
//! Accuracy is a **request dimension**, orthogonal to route: every plan
//! carries the requested [`Accuracy`] tier (Naive / Kahan / Dot2 / Exact)
//! and the dispatch table holds a per-tier winner per `(precision, size
//! class)` cell, so `plan → select` always lands on a kernel of the
//! requested tier. Routing never changes bits *within* a tier — the
//! bit-identity invariants below hold per tier, and the tier's sequential
//! error bound (Kahan's `2eps`-per-step, Dot2's `eps + O(eps²)·cond`)
//! survives every route because partials merge through the same
//! compensated flat fold the sequential kernels use. The `Exact` tier is
//! the one exception to free routing: its expansion arithmetic is scalar
//! and latency-dominated, so [`PlanPolicy::plan_dot`] routes it
//! [`DotRoute::Inline`] unconditionally (one worker, no SIMD claim, no
//! split) — correctly-rounded results have no partial-merge story.
//!
//! # Length policy
//!
//! THE one place the policy is defined: `dot_*`/`dot_pooled_*` compute
//! over the first `min(a.len(), b.len())` elements of each stream.
//! Mismatched lengths are a caller bug — the engine `debug_assert`s
//! equality (so test builds catch drift) but truncates in release rather
//! than panicking on the hot path. Public request surfaces
//! (`coordinator::service`) reject mismatched requests *before* they
//! reach the engine; keep it that way. Plans are always computed from the
//! truncated length.
//!
//! # Batching invariant
//!
//! **Batching never changes bits.** The engine's `dot_batch_*`, the
//! sharded tier's `dot_batch_*`/`dot_batch_on_*`/`dot_batch_homed_*`, and
//! the service's lane coalescing all return, for every request in a
//! batch, exactly the value the serial single-request path returns. The
//! mechanism: requests the planner routes [`DotRoute::Inline`] are
//! grouped (one worker handoff per chunk-group instead of one per
//! request) and executed either by a fused multi-dot kernel
//! (`bench::kernels::batch`) that interleaves requests across unroll
//! slots while keeping each request's own operation sequence identical to
//! its single-dot kernel, or by a serial loop of that same single kernel;
//! requests the planner routes [`DotRoute::Parallel`] or
//! [`DotRoute::Split`] take the exact serial route, one by one. The fused
//! kernels are only reachable through [`batch_exec`], which consults the
//! dispatch table — the table pairs them with the single winner of the
//! same `(precision, accuracy, size class)` cell and keeps them only
//! below the calibrated batch-size cutoff. Tiers without fused twins
//! (Dot2, Exact) fall back to the serial loop of the tier's single
//! winner — fuse-or-loop, bit-identical to serial resubmission either
//! way.
//! Property-tested on Ogita–Rump–Oishi inputs at every layer in
//! `rust/tests/test_batch.rs` and against the planner in
//! `rust/tests/test_plan.rs`.
//!
//! # ECM governance
//!
//! **The worker cap changes concurrency only, never bits.** The policy
//! carries per-(precision, size-class) worker caps derived from the ECM
//! saturation prediction (`ecm::governance`: a memory-bound dot stops
//! scaling at n_S = ceil(T_ECM^mem / T_L3Mem) cores, so workers past
//! saturation are pure contention). The planner only *stores and reports*
//! the cap ([`PlanPolicy::worker_cap`]); the execution layers realize it
//! by running the planned chunks on a worker *subset*
//! (`WorkerPool::subset_start` + modulo placement) while the freed
//! workers serve concurrent requests from other lanes. Chunk and split
//! geometry stay planner-derived ([`PlanPolicy::split_blocks`],
//! cache-line-quanta `chunk_ranges`) independent of the realized worker
//! count, and partials always merge in chunk order — so capped and
//! uncapped execution are bit-identical and the sequential Kahan bound
//! survives unchanged (property-tested in `rust/tests/test_plan.rs` and
//! `rust/tests/test_engine.rs`). Within a precision the caps are monotone
//! non-increasing in the size class: growing a working set can only move
//! it toward the shared-bandwidth ceiling. The empirical correction loop
//! (`DispatchTable::note_saturation`/`corrected_sat`) lives with the
//! autotuner's calibration state, keeping this policy pure.
//!
//! # Overload shedding
//!
//! **Shedding rejects whole requests and never changes the bits of served
//! ones.** The ECM analysis is why the policy exists at all: a
//! memory-bound Kahan dot saturates bandwidth at a few cores, so past
//! saturation extra traffic cannot buy throughput — it can only grow
//! queues. The old bounded-queue-blocks-the-sender design turned that
//! into a priority inversion (one slow client stalls its whole submitter
//! lane); the shed policy turns it into a clean, counted reject instead.
//! The decision is pure and lives HERE, per the planner-extension-point
//! rule: [`PlanPolicy::shed`] compares a request's admission deadline
//! against the lane's projected queue wait (its queued depth × the
//! per-message service-time estimate from the lane's latency histogram)
//! and its configured depth ([`PlanPolicy::with_admission`]), returning a
//! [`ShedVerdict`] when the request cannot make its deadline — the
//! service replies `Err("shed: …")` immediately instead of blocking the
//! sender. Requests without a deadline (`deadline_us == 0`) keep the old
//! blocking back-pressure: shedding is strictly opt-in per request.
//! [`PlanPolicy::admits_client`] is the companion fairness predicate:
//! with a per-client in-flight cap configured, a client already holding
//! `cap` slots of a lane's queue is shed (`"shed: client …"`) so one
//! heavy client cannot starve the lane for everyone else. A shed request
//! never reaches an engine, so every bit-identity invariant above is
//! untouched — property-tested in `coordinator/service/tests.rs` by
//! serially resubmitting everything a shedding service served.
//!
//! A [`ShedVerdict`] also carries a **retry-after hint**
//! (`retry_after_us`, computed from the same projection): the earliest
//! time at which resubmitting could plausibly be admitted. It is
//! advisory — the lane's state moves on — but it is what
//! `DotClient::submit_with_retry` uses to pace its capped exponential
//! backoff instead of hammering a lane the projection already called
//! full.
//!
//! # Fault domains
//!
//! **Quarantine never changes bits.** The supervision layer (see the
//! fault-domains box in the [`super`] module diagram) may declare a
//! shard unhealthy — its workers exhausted the service's respawn budget
//! — and *quarantine* it. The planner's contract for that state keeps
//! the two repo invariants intact:
//!
//! * [`PlanPolicy::split_chunk_count`] is **unchanged** by quarantine:
//!   it still counts every shard's workers, so a split dot's chunk
//!   geometry and merge order are identical with 0, 1, or N shards
//!   quarantined — the same reason the ECM caps never change bits.
//! * [`PlanPolicy::split_blocks_masked`] re-weights the chunk→shard
//!   *assignment* over the healthy shards only (a quarantined shard gets
//!   no blocks, its share going to its neighbors by the same
//!   deterministic cumulative-weight rounding). Assignment is pure
//!   placement: every chunk computes the same partial wherever it runs,
//!   and the flat compensated fold still merges in global chunk order,
//!   so a quarantined split is bit-identical to a healthy one
//!   (property-tested in this module and `rust/tests/test_faults.rs`).
//! * Fresh-request routing simply skips quarantined shards (router
//!   round-robin over the healthy set); pooled streams homed on a
//!   quarantined shard keep serving there — moving them would change
//!   their NUMA placement story, not their bits, but re-admission is the
//!   client's call, not the router's.
//! * With **every** shard quarantined the mask is ignored (serving
//!   degraded beats serving nothing); probes reinstate shards as they
//!   recover.
//!
//! # Calibration
//!
//! **Measured numbers may change thresholds and routing, never chunk
//! geometry or bits.** A persisted [`super::profile::CalibrationProfile`]
//! (measured once per machine, loaded at startup) enters the planner as
//! an optional [`PlanCalibration`]: projected one-shard and all-shard
//! bandwidths per `(precision, size class)`, the fixed fan-out cost of a
//! split, and the measured per-class accuracy-tier throughput ratios.
//! What it drives:
//!
//! * `split_min_bytes` — `ShardedEngine::from_topology` derives the
//!   route threshold from the measured crossover
//!   (`CalibrationProfile::derived_split_min_bytes`) when the config
//!   leaves it 0 (= auto); without a profile the documented 4 MiB
//!   default (`sharded::DEFAULT_SPLIT_MIN_BYTES`) stands. A threshold
//!   only moves the Inline/Parallel/Split boundary — within any route
//!   the result is bit-identical, so calibrated and default policies
//!   agree bit-for-bit on every request (property-tested across
//!   no-profile / synthetic-low / synthetic-high policies in
//!   `rust/tests/test_profile.rs`).
//! * **Deadline-aware routing** — [`PlanPolicy::plan_dot_deadline`]:
//!   when a request carries a deadline, the projected one-shard time
//!   blows it, and the projected split time fits, the plan is promoted
//!   Parallel → Split (`DotPlan::deadline_promoted`). Promotion is
//!   gated on bit-safety: it fires only when
//!   [`PlanPolicy::split_chunk_count`] equals the executing shard's
//!   worker count, so the split executes the SAME chunk geometry, the
//!   same total-size-selected kernel, and the same compensated
//!   chunk-order merge the one-shard path would have — routing changes,
//!   bits cannot (the quarantine argument, applied to promotion).
//! * **Free accuracy upgrades** — [`PlanPolicy::upgrade_accuracy`]:
//!   when the measured `kahan_vs_naive` ratio for the request's class is
//!   ≥ [`FREE_UPGRADE_RATIO`], a Naive request is served Kahan (more
//!   accurate at measured-zero cost; the paper's thesis applied as
//!   policy). Opt-out via `ServiceConfig::auto_upgrade_accuracy`; the
//!   upgrade intentionally changes the *tier* — bit-identity invariants
//!   are per tier and unaffected.
//! * Autotuner seeding — `DispatchTable::from_profile` starts the
//!   process on the persisted winners and saturation corrections
//!   instead of from zero (kernel *selection* and concurrency only).
//!
//! A corrupt, stale, or version-mismatched profile is rejected whole
//! (counted in `profile_rejected`), leaving every default in place —
//! calibration can tune this planner, never break its contracts.
//!
//! # Who consumes plans
//!
//! * `DotEngine` — [`serves_inline`] is the inline-vs-parallel predicate
//!   (shared by its serial and batch paths, so both split a request set
//!   identically — anything else would break the batching invariant);
//! * `ShardedEngine` — [`PlanPolicy::plan_dot`] routes every request,
//!   [`PlanPolicy::split_chunk_count`]/[`PlanPolicy::split_blocks`]
//!   compile the weighted cross-shard split geometry (whose flat
//!   compensated merge keeps the sequential Kahan bound);
//! * `coordinator::service` — lanes ask [`PlanPolicy::batch_window`]
//!   whether a bounded wait-for-k is worth the latency (only when the
//!   fused kernel wins at the projected batch size), and the batch
//!   executors ask [`batch_exec`] whether a run fuses;
//! * `repro plan` — the CLI prints a plan and its reasons, which makes
//!   the planner a debugging/teaching tool.

use super::autotune::{DispatchTable, SizeClass};
use crate::bench::kernels::batch::BatchKernel;
use crate::isa::{Accuracy, Precision};
use std::time::Duration;

/// How one dot request executes. Ordered by working-set size: as a
/// request grows it can only move Inline → Parallel → Split (the
/// monotonicity property test leans on the derived `Ord`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DotRoute {
    /// one kernel call on the submitting thread — no handoff, no copy
    Inline,
    /// chunked compensated reduction across ONE shard's pinned workers
    Parallel,
    /// weighted split across every shard, merged by the flat compensated
    /// fold over global per-chunk partials (sequential Kahan bound and
    /// 1-vs-N-shard bit-identity both survive)
    Split,
}

impl DotRoute {
    pub fn name(self) -> &'static str {
        match self {
            DotRoute::Inline => "inline",
            DotRoute::Parallel => "one-shard parallel",
            DotRoute::Split => "cross-shard split",
        }
    }
}

/// The compiled plan for one request: where it runs and what the
/// autotuner knows about its size.
#[derive(Clone, Copy, Debug)]
pub struct DotPlan {
    pub route: DotRoute,
    /// executing shard for `Inline` / `Parallel` (the caller's preferred
    /// shard, clamped into range); for `Split` the shard the cursor
    /// suggested — execution fans out over every shard and ignores it
    pub shard: usize,
    /// size class of the total working set on this host
    pub class: SizeClass,
    /// total working set (both streams, bytes) the plan was compiled for
    pub total_bytes: u64,
    /// requested accuracy tier — the dispatch column `select` resolves
    /// against, carried so every execution layer serves the tier the
    /// request asked for
    pub accuracy: Accuracy,
    /// deadline-aware routing promoted this plan Parallel → Split (see
    /// the module's "# Calibration" section): the projected one-shard
    /// time blew the request's deadline, the split projection fit, and
    /// the geometry gate held — so the promotion changed the route but
    /// cannot change the bits
    pub deadline_promoted: bool,
}

/// The planner-facing slice of a measured [`super::profile::CalibrationProfile`]:
/// projected service bandwidths plus the measured accuracy-tier ratios.
/// Pure data — installed via [`PlanPolicy::with_calibration`], consumed
/// by [`PlanPolicy::plan_dot_deadline`] and
/// [`PlanPolicy::upgrade_accuracy`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCalibration {
    /// projected service bandwidth of one (the widest) shard, GB/s,
    /// `[precision][size class]`; 0 = no measurement for the cell
    pub shard_gbs: [[f64; 3]; 2],
    /// projected bandwidth of a split across every shard, GB/s,
    /// `[precision][size class]` (saturation-capped, so it may equal
    /// `shard_gbs` where the bus is the ceiling)
    pub split_gbs: [[f64; 3]; 2],
    /// fixed fan-out + compensated-merge cost a split pays, µs
    pub split_fixed_us: f64,
    /// measured f32 kahan/naive throughput ratio per size class
    pub kahan_vs_naive: [f64; 3],
    /// measured f32 dot2/naive throughput ratio per size class
    pub dot2_vs_naive: [f64; 3],
}

impl PlanCalibration {
    /// Projected one-shard (chunked-parallel) service time, µs. `None`
    /// when the profile has no throughput figure for the cell.
    pub fn projected_parallel_us(
        &self,
        prec: Precision,
        class: SizeClass,
        total_bytes: u64,
    ) -> Option<f64> {
        let gbs = self.shard_gbs[super::autotune::prec_index(prec)][class.index()];
        // GB/s → bytes/µs is ×1000
        if gbs > 0.0 { Some(total_bytes as f64 / (gbs * 1000.0)) } else { None }
    }

    /// Projected cross-shard split service time (bandwidth share plus the
    /// measured fixed fan-out cost), µs.
    pub fn projected_split_us(
        &self,
        prec: Precision,
        class: SizeClass,
        total_bytes: u64,
    ) -> Option<f64> {
        let gbs = self.split_gbs[super::autotune::prec_index(prec)][class.index()];
        if gbs > 0.0 {
            Some(total_bytes as f64 / (gbs * 1000.0) + self.split_fixed_us.max(0.0))
        } else {
            None
        }
    }
}

/// A measured `kahan/naive` throughput ratio at or above this means the
/// compensated tier is free on this machine and class — the auto-upgrade
/// predicate's threshold (the paper's "Kahan costs nothing once
/// memory-bound" thesis, with 5% measurement slack).
pub const FREE_UPGRADE_RATIO: f64 = 0.95;

/// The inline-vs-parallel predicate, shared verbatim by the engine's
/// serial and batch paths: a dot whose total working set (both streams)
/// is under the cutoff — or an engine with a single worker — runs on the
/// submitting thread, because a worker handoff would cost more than it
/// amortizes. An EMPTY dot (`total_bytes == 0`) is always inline, even
/// under a forced cutoff of 0: there is nothing to hand a worker, and the
/// zero-length property test pins that it never reaches one.
pub fn serves_inline(total_bytes: u64, parallel_cutoff_bytes: usize, workers: usize) -> bool {
    total_bytes == 0 || total_bytes < parallel_cutoff_bytes as u64 || workers <= 1
}

/// Fuse-or-loop decision for one same-class run inside a batch: the fused
/// multi-dot twin of the cell's single winner, if the run is long enough
/// to fuse (≥ 2) and calibration kept a twin for this cell (the table's
/// cutoff is monotone over size classes and always serial for
/// memory-resident dots). `None` means: loop the single winner — request
/// coalescing above the kernel still applies, bits never change either
/// way.
pub fn batch_exec(
    table: &DispatchTable,
    prec: Precision,
    accuracy: Accuracy,
    class: SizeClass,
    run_len: usize,
) -> Option<&'static BatchKernel> {
    if run_len < 2 {
        return None;
    }
    table.select_batch(prec, accuracy, class)
}

/// Every machine-dependent threshold the serving stack routes by, in one
/// place. Built from the engine configuration plus the discovered
/// topology (per-shard worker counts), optionally extended with the
/// service's batching knobs via [`PlanPolicy::with_service`].
#[derive(Clone, Debug)]
pub struct PlanPolicy {
    /// below this total working set (both streams, bytes) a dot runs
    /// inline on the submitting thread (`EngineConfig::parallel_cutoff_bytes`)
    pub parallel_cutoff_bytes: usize,
    /// at or above this total working set a dot splits across every shard
    /// (`ShardedConfig::split_min_bytes`)
    pub split_min_bytes: usize,
    /// global chunk count for split dots; 0 = one chunk per worker
    /// (`ShardedConfig::chunks`) — fixing it fixes the chunk geometry,
    /// making split results bit-identical for any shard count
    pub split_chunks: usize,
    /// worker count of each shard (index == shard); never empty
    pub shard_workers: Vec<usize>,
    /// service: max requests fused into one batched execute (1 = no
    /// coalescing); engines that never batch leave the default 1
    pub max_batch: usize,
    /// service: latency-aware adaptive batching — the bounded wait-for-k
    /// window in microseconds. 0 = purely opportunistic coalescing
    /// (today's zero-added-latency behavior)
    pub batch_window_us: u64,
    /// ECM governance: worker cap per `[precision][size class]`
    /// (`usize::MAX` = uncapped; see the module's "ECM governance"
    /// section). Defaults to all-uncapped — governance is opt-in via
    /// [`PlanPolicy::with_governance`].
    pub worker_caps: [[usize; 3]; 2],
    /// service: the bounded depth of one submitter lane's queue
    /// (`ServiceConfig::router_queue_depth`), installed via
    /// [`PlanPolicy::with_admission`] so [`PlanPolicy::shed`] can treat a
    /// full lane as an unconditional miss for deadlined requests.
    /// `usize::MAX` (default) = depth unknown, never "full".
    pub lane_depth: usize,
    /// service: per-client in-flight cap per lane (fair admission). 0
    /// (default) = unlimited — [`PlanPolicy::admits_client`] admits
    /// everything, the pre-fairness behavior.
    pub per_client_inflight: usize,
    /// measured-calibration projections (see "# Calibration"); `None`
    /// (default) = no profile — deadline-aware routing and free upgrades
    /// are inert and every threshold keeps its built-in default
    pub calibration: Option<PlanCalibration>,
    /// serve Naive requests at the Kahan tier where the measured ratio
    /// says compensation is free (`ServiceConfig::auto_upgrade_accuracy`).
    /// Defaults off at the planner layer — only the service opts in, so
    /// raw engine paths never reinterpret a tier.
    pub auto_upgrade: bool,
}

/// Why a request was shed at admission instead of queued: the evidence
/// [`PlanPolicy::shed`] compared against the request's deadline. Carried
/// into the request's `Err("shed: …")` reply so a client sees the lane
/// state that rejected it, and into `repro plan`'s explain output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShedVerdict {
    /// the request's admission deadline (µs)
    pub deadline_us: u64,
    /// messages queued on the lane when the request arrived
    pub queued: usize,
    /// projected queue wait: `queued ×` the lane's per-message
    /// service-time estimate (µs, from its latency histogram)
    pub projected_wait_us: u64,
    /// the lane's bounded queue was full — an unconditional miss: the
    /// alternative is exactly the blocking send the policy exists to
    /// remove
    pub queue_full: bool,
    /// retry-after hint (µs): the earliest resubmission that could
    /// plausibly be admitted, from the same projection that shed this
    /// request — how long the excess projected wait takes to drain, never
    /// less than one service time. Advisory; consumed by
    /// `DotClient::submit_with_retry` to pace its backoff.
    pub retry_after_us: u64,
}

impl PlanPolicy {
    /// Policy for an engine tier: thresholds plus the realized per-shard
    /// worker counts. Service knobs default to "no batching window".
    pub fn new(
        parallel_cutoff_bytes: usize,
        split_min_bytes: usize,
        split_chunks: usize,
        shard_workers: Vec<usize>,
    ) -> PlanPolicy {
        assert!(!shard_workers.is_empty(), "a plan policy needs at least one shard");
        PlanPolicy {
            parallel_cutoff_bytes,
            split_min_bytes,
            split_chunks,
            shard_workers,
            max_batch: 1,
            batch_window_us: 0,
            worker_caps: [[usize::MAX; 3]; 2],
            lane_depth: usize::MAX,
            per_client_inflight: 0,
            calibration: None,
            auto_upgrade: false,
        }
    }

    /// Install measured-calibration projections (see "# Calibration").
    pub fn with_calibration(mut self, calibration: PlanCalibration) -> PlanPolicy {
        self.calibration = Some(calibration);
        self
    }

    /// Enable/disable the free naive→kahan upgrade
    /// ([`PlanPolicy::upgrade_accuracy`]); effective only with a
    /// calibration installed.
    pub fn with_upgrade(mut self, auto_upgrade: bool) -> PlanPolicy {
        self.auto_upgrade = auto_upgrade;
        self
    }

    /// Extend an engine policy with the service's batching knobs.
    pub fn with_service(mut self, max_batch: usize, batch_window_us: u64) -> PlanPolicy {
        self.max_batch = max_batch;
        self.batch_window_us = batch_window_us;
        self
    }

    /// Extend a service policy with the admission knobs the overload
    /// layer routes by: the lane queue depth (so [`PlanPolicy::shed`] can
    /// recognize a full lane) and the per-client in-flight cap
    /// (0 = unlimited, see [`PlanPolicy::admits_client`]).
    pub fn with_admission(mut self, lane_depth: usize, per_client_inflight: usize) -> PlanPolicy {
        self.lane_depth = lane_depth;
        self.per_client_inflight = per_client_inflight;
        self
    }

    /// Install ECM-derived worker caps (`[precision][size class]`,
    /// `usize::MAX` = uncapped), e.g. `EcmVerdict::worker_caps()`.
    pub fn with_governance(mut self, caps: [[usize; 3]; 2]) -> PlanPolicy {
        self.worker_caps = caps;
        self
    }

    /// Strip every worker cap (the `ecm_governance=off` control path).
    pub fn ungoverned(self) -> PlanPolicy {
        self.with_governance([[usize::MAX; 3]; 2])
    }

    /// The governance worker cap for one `(precision, size class)` cell.
    /// `usize::MAX` = uncapped; execution layers additionally clamp to
    /// the realized worker count and apply the autotuner's empirical
    /// saturation correction (`DispatchTable::corrected_sat`).
    pub fn worker_cap(&self, prec: Precision, class: SizeClass) -> usize {
        self.worker_caps[super::autotune::prec_index(prec)][class.index()]
    }

    /// Would governance actually bind on `shard` — i.e. is the cap
    /// strictly below the shard's realized worker count?
    pub fn governed(&self, shard: usize, prec: Precision, class: SizeClass) -> bool {
        self.worker_cap(prec, class) < self.shard_workers[self.clamp_shard(shard)]
    }

    pub fn shards(&self) -> usize {
        self.shard_workers.len()
    }

    pub fn total_workers(&self) -> usize {
        self.shard_workers.iter().sum()
    }

    /// Clamp a preferred shard into range (round-robin cursors overshoot
    /// by design).
    pub fn clamp_shard(&self, shard: usize) -> usize {
        shard % self.shard_workers.len()
    }

    /// THE split predicate: does a dot of this total working set fan out
    /// across every shard? An empty dot never splits, even under a forced
    /// `split_min_bytes` of 0 — there are no chunks to deal out.
    pub fn splits(&self, total_bytes: u64) -> bool {
        total_bytes > 0 && total_bytes >= self.split_min_bytes as u64
    }

    /// THE inline predicate for a given shard (its worker count decides
    /// whether a handoff can pay for itself).
    pub fn serves_inline_on(&self, shard: usize, total_bytes: u64) -> bool {
        serves_inline(
            total_bytes,
            self.parallel_cutoff_bytes,
            self.shard_workers[self.clamp_shard(shard)],
        )
    }

    /// Compile the plan for one dot of `total_bytes` (both streams) whose
    /// router preferred `preferred_shard`, at the requested accuracy tier.
    /// Deterministic and monotone in `total_bytes`: for a fixed policy,
    /// shard and tier, a larger request never takes an earlier route
    /// (Inline → Parallel → Split). The `Exact` tier is the exception to
    /// size-based routing: its scalar expansion arithmetic has no
    /// partial-merge story, so it is always Inline on one worker.
    pub fn plan_dot(&self, preferred_shard: usize, accuracy: Accuracy, total_bytes: u64) -> DotPlan {
        let shard = self.clamp_shard(preferred_shard);
        let route = if accuracy == Accuracy::Exact {
            DotRoute::Inline
        } else if self.splits(total_bytes) {
            DotRoute::Split
        } else if self.serves_inline_on(shard, total_bytes) {
            DotRoute::Inline
        } else {
            DotRoute::Parallel
        };
        DotPlan {
            route,
            shard,
            class: SizeClass::of(total_bytes),
            total_bytes,
            accuracy,
            deadline_promoted: false,
        }
    }

    /// [`PlanPolicy::plan_dot`] for a request that carries a deadline:
    /// identical, except that a Parallel plan whose projected one-shard
    /// time blows the deadline while the projected split time fits is
    /// promoted to [`DotRoute::Split`] (see "# Calibration"). The
    /// promotion is gated on bit-safety — it fires only when the split's
    /// global chunk count equals the executing shard's worker count, so
    /// the promoted route runs the same chunk geometry, the same
    /// total-size-selected kernel, and the same compensated chunk-order
    /// merge the un-promoted route would have. `deadline_us == 0` (no
    /// deadline), no calibration, or a failed gate all reduce to
    /// `plan_dot` exactly.
    pub fn plan_dot_deadline(
        &self,
        preferred_shard: usize,
        accuracy: Accuracy,
        prec: Precision,
        total_bytes: u64,
        deadline_us: u64,
    ) -> DotPlan {
        let mut plan = self.plan_dot(preferred_shard, accuracy, total_bytes);
        if deadline_us == 0 || plan.route != DotRoute::Parallel {
            return plan;
        }
        // bit-safety gate: the promoted split must reproduce the
        // one-shard chunk geometry exactly
        if self.split_chunk_count() != self.shard_workers[plan.shard] {
            return plan;
        }
        let Some(c) = self.calibration else { return plan };
        let (Some(par), Some(spl)) = (
            c.projected_parallel_us(prec, plan.class, total_bytes),
            c.projected_split_us(prec, plan.class, total_bytes),
        ) else {
            return plan;
        };
        let deadline = deadline_us as f64;
        if par > deadline && spl <= deadline && spl < par {
            plan.route = DotRoute::Split;
            plan.deadline_promoted = true;
        }
        plan
    }

    /// THE free-upgrade decision (see "# Calibration"): the tier a
    /// request is actually served at, plus the measured ratio that
    /// justified an upgrade. Only `Naive` can upgrade (to `Kahan`), only
    /// when upgrades are enabled AND a calibration is installed AND the
    /// measured `kahan_vs_naive` ratio for the request's size class is at
    /// least [`FREE_UPGRADE_RATIO`] — compensation measured free on this
    /// machine. Every other tier passes through untouched: an explicit
    /// Kahan/Dot2/Exact request is already getting what it asked for.
    pub fn upgrade_accuracy(&self, accuracy: Accuracy, total_bytes: u64) -> (Accuracy, Option<f64>) {
        if accuracy != Accuracy::Naive || !self.auto_upgrade {
            return (accuracy, None);
        }
        let Some(c) = self.calibration else { return (accuracy, None) };
        let ratio = c.kahan_vs_naive[SizeClass::of(total_bytes).index()];
        if ratio >= FREE_UPGRADE_RATIO {
            (Accuracy::Kahan, Some(ratio))
        } else {
            (accuracy, None)
        }
    }

    /// Global chunk count for a split dot (the explicit override, or one
    /// chunk per worker across the whole shard set).
    pub fn split_chunk_count(&self) -> usize {
        if self.split_chunks == 0 {
            self.total_workers()
        } else {
            self.split_chunks
        }
    }

    /// The weighted split assignment: contiguous chunk blocks
    /// `(shard, chunk_lo, chunk_hi)` per shard, weighted by each shard's
    /// worker count (equal-count dealing would hand an 8-worker and a
    /// 16-worker domain the same share and re-create the straggler
    /// imbalance one level up). Boundaries are the deterministic
    /// cumulative-weight rounding, so the assignment never affects the
    /// partials or the compensated fold that merges them.
    pub fn split_blocks(&self, chunk_count: usize) -> Vec<(usize, usize, usize)> {
        self.split_blocks_masked(chunk_count, &[])
    }

    /// [`PlanPolicy::split_blocks`] over the *healthy* shards only — the
    /// quarantine form (see "# Fault domains"): shards whose `healthy`
    /// entry is `false` get no blocks, their share re-weighted onto the
    /// healthy shards by the same cumulative rounding. The chunk count
    /// (and with it every chunk boundary and the merge order) is the
    /// caller's and does NOT shrink with the mask, so a quarantined split
    /// is bit-identical to a healthy one. An empty mask, a mask of the
    /// wrong length, or an all-unhealthy mask means "no quarantine":
    /// every shard is weighted (serving degraded beats serving nothing).
    pub fn split_blocks_masked(
        &self,
        chunk_count: usize,
        healthy: &[bool],
    ) -> Vec<(usize, usize, usize)> {
        let masked = healthy.len() == self.shard_workers.len() && healthy.iter().any(|&h| h);
        let weight = |s: usize| -> usize {
            if masked && !healthy[s] {
                0
            } else {
                self.shard_workers[s]
            }
        };
        let total_w = (0..self.shard_workers.len()).map(weight).sum::<usize>().max(1);
        let mut blocks: Vec<(usize, usize, usize)> = Vec::with_capacity(self.shard_workers.len());
        let mut cum = 0usize;
        let mut prev = 0usize;
        for s in 0..self.shard_workers.len() {
            cum += weight(s);
            let end = chunk_count * cum / total_w;
            if end > prev {
                blocks.push((s, prev, end));
                prev = end;
            }
        }
        blocks
    }

    /// Latency-aware adaptive batching: how long a service lane that woke
    /// up with `queued_dots` coalescible dots may wait for more before
    /// executing. `Some` only when every condition holds:
    ///
    /// * a window is configured (`batch_window_us > 0`) and batching is on
    ///   (`max_batch ≥ 2`);
    /// * there is a run to grow (`queued_dots ≥ 1`) that is not already a
    ///   full batch (`queued_dots < max_batch`);
    /// * the caller confirmed the fused kernel wins at the projected
    ///   batch size (`fused_wins` — i.e. calibration kept a fused twin
    ///   for the run's dispatch cell; where fusion lost the probe, added
    ///   latency buys nothing, so the lane must not wait).
    ///
    /// With `batch_window_us == 0` this is always `None`: the lane keeps
    /// today's purely opportunistic, zero-added-latency behavior.
    pub fn batch_window(&self, queued_dots: usize, fused_wins: bool) -> Option<Duration> {
        if self.batch_window_us == 0
            || self.max_batch < 2
            || !fused_wins
            || queued_dots == 0
            || queued_dots >= self.max_batch
        {
            return None;
        }
        Some(Duration::from_micros(self.batch_window_us))
    }

    /// THE admission-shed decision (see the module's "Overload shedding"
    /// section): should a request with this deadline be rejected instead
    /// of queued on a lane that currently holds `queued` messages and
    /// serves one in about `est_service_us` µs (the caller derives the
    /// estimate from the lane's latency histogram; 0 = no data yet)?
    ///
    /// `None` = admit. `Some` when either
    /// * the lane is full (`queued ≥ lane_depth`) — admitting would block
    ///   the sender, which is exactly the priority inversion this policy
    ///   removes; or
    /// * the projected queue wait (`queued × est_service_us`) already
    ///   exceeds the deadline — the request would only be served late and
    ///   meanwhile occupy a queue slot someone else could make.
    ///
    /// `deadline_us == 0` means "no deadline": always admit — such
    /// requests keep the blocking back-pressure semantics, so shedding is
    /// strictly opt-in per request. Pure: expiry of already-queued
    /// requests is the service's clock to keep, not the planner's.
    pub fn shed(
        &self,
        deadline_us: u64,
        queued: usize,
        est_service_us: u64,
    ) -> Option<ShedVerdict> {
        if deadline_us == 0 {
            return None;
        }
        let queue_full = queued >= self.lane_depth;
        let projected_wait_us = (queued as u64).saturating_mul(est_service_us);
        if queue_full || projected_wait_us > deadline_us {
            // retry-after: how long the projection says the *excess* wait
            // takes to drain — at least one service time (a full lane with
            // no histogram data yet still needs one serve to free a slot),
            // and never 0 (an immediate retry would meet the same verdict)
            let retry_after_us = projected_wait_us
                .saturating_sub(deadline_us)
                .max(est_service_us)
                .max(1);
            Some(ShedVerdict { deadline_us, queued, projected_wait_us, queue_full, retry_after_us })
        } else {
            None
        }
    }

    /// THE fair-admission predicate: may a client that already holds
    /// `inflight` slots of a lane's queue take one more? With no cap
    /// configured (`per_client_inflight == 0`) always yes; otherwise only
    /// below the cap — the request of a client at its cap is shed so one
    /// heavy client cannot occupy a whole lane and starve its neighbors.
    pub fn admits_client(&self, inflight: usize) -> bool {
        self.per_client_inflight == 0 || inflight < self.per_client_inflight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> PlanPolicy {
        PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![2, 2])
    }

    #[test]
    fn routes_partition_the_size_axis() {
        let p = policy();
        for acc in [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2] {
            assert_eq!(p.plan_dot(0, acc, 1024).route, DotRoute::Inline);
            assert_eq!(p.plan_dot(0, acc, (256 * 1024) - 1).route, DotRoute::Inline);
            assert_eq!(p.plan_dot(0, acc, 256 * 1024).route, DotRoute::Parallel);
            assert_eq!(p.plan_dot(0, acc, (4 << 20) - 1).route, DotRoute::Parallel);
            assert_eq!(p.plan_dot(0, acc, 4 << 20).route, DotRoute::Split);
        }
        // a single-worker shard never goes parallel, but still splits
        let single = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![1]);
        assert_eq!(single.plan_dot(0, Accuracy::Kahan, 1 << 20).route, DotRoute::Inline);
        assert_eq!(single.plan_dot(0, Accuracy::Kahan, 8 << 20).route, DotRoute::Split);
    }

    #[test]
    fn exact_tier_always_plans_inline() {
        let p = policy();
        for bytes in [1024u64, 256 * 1024, 4 << 20, 64 << 20] {
            let plan = p.plan_dot(1, Accuracy::Exact, bytes);
            assert_eq!(plan.route, DotRoute::Inline, "exact never parallelizes or splits");
            assert_eq!(plan.shard, 1, "still lands on the preferred shard");
            assert_eq!(plan.accuracy, Accuracy::Exact);
        }
    }

    #[test]
    fn preferred_shard_is_clamped_not_dropped() {
        let p = policy();
        assert_eq!(p.plan_dot(5, Accuracy::Kahan, 1024).shard, 1);
        assert_eq!(p.plan_dot(4, Accuracy::Kahan, 1024).shard, 0);
    }

    #[test]
    fn split_blocks_are_weighted_contiguous_and_exhaustive() {
        let p = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![8, 16]);
        let blocks = p.split_blocks(24);
        assert_eq!(blocks, vec![(0, 0, 8), (1, 8, 24)]);
        // fewer chunks than shards: a shard may get nothing, but coverage
        // stays contiguous and complete
        let b1 = p.split_blocks(1);
        assert_eq!(b1.iter().map(|&(_, lo, hi)| hi - lo).sum::<usize>(), 1);
        assert_eq!(b1.last().unwrap().2, 1);
    }

    /// The quarantine contract ("# Fault domains"): a masked shard gets
    /// no blocks, coverage stays contiguous and complete over the SAME
    /// chunk count (geometry never shrinks with the mask), degenerate
    /// masks fall back to the unmasked weighting, and the unmasked call
    /// is exactly `split_blocks`.
    #[test]
    fn split_blocks_masked_requarantines_weights_without_changing_chunks() {
        let p = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![8, 16, 8]);
        for chunks in [1usize, 7, 24, 32] {
            for mask in [
                vec![true, true, true],
                vec![false, true, true],
                vec![true, false, true],
                vec![true, true, false],
                vec![true, false, false],
            ] {
                let blocks = p.split_blocks_masked(chunks, &mask);
                // exhaustive contiguous coverage of [0, chunks)
                assert_eq!(blocks.first().unwrap().1, 0, "{mask:?}");
                assert_eq!(blocks.last().unwrap().2, chunks, "{mask:?}");
                for w in blocks.windows(2) {
                    assert_eq!(w[0].2, w[1].1, "contiguous {mask:?}");
                }
                // a quarantined shard never receives a block
                for &(s, lo, hi) in &blocks {
                    assert!(mask[s], "shard {s} is quarantined but got chunks {lo}..{hi}");
                    assert!(hi > lo);
                }
            }
            // all-unhealthy and wrong-length masks fall back to unmasked
            assert_eq!(
                p.split_blocks_masked(chunks, &[false, false, false]),
                p.split_blocks(chunks),
                "all-quarantined must serve degraded, not empty"
            );
            assert_eq!(p.split_blocks_masked(chunks, &[true]), p.split_blocks(chunks));
            assert_eq!(p.split_blocks_masked(chunks, &[]), p.split_blocks(chunks));
        }
        // the weighted re-deal: masking the 16-worker middle shard splits
        // 24 chunks evenly over the two 8-worker survivors
        assert_eq!(
            p.split_blocks_masked(24, &[true, false, true]),
            vec![(0, 0, 12), (2, 12, 24)]
        );
    }

    #[test]
    fn governance_caps_default_open_and_round_trip() {
        let p = policy();
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                assert_eq!(p.worker_cap(prec, class), usize::MAX, "default is uncapped");
                assert!(!p.governed(0, prec, class), "uncapped never governs");
            }
        }
        let caps = [[usize::MAX, usize::MAX, 1], [usize::MAX, 2, 1]];
        let g = policy().with_governance(caps);
        assert_eq!(g.worker_cap(Precision::Sp, SizeClass::Mem), 1);
        assert_eq!(g.worker_cap(Precision::Dp, SizeClass::Llc), 2);
        assert_eq!(g.worker_cap(Precision::Sp, SizeClass::L1), usize::MAX);
        // binds only where the cap undercuts the shard's worker count (2)
        assert!(g.governed(0, Precision::Sp, SizeClass::Mem));
        assert!(!g.governed(0, Precision::Dp, SizeClass::Llc), "cap == workers does not bind");
        assert!(!g.governed(1, Precision::Sp, SizeClass::L1));
        // the off switch restores the open policy
        let off = g.ungoverned();
        assert_eq!(off.worker_cap(Precision::Dp, SizeClass::Mem), usize::MAX);
    }

    #[test]
    fn batch_window_requires_every_condition() {
        let p = policy().with_service(4, 100);
        assert_eq!(p.batch_window(1, true), Some(Duration::from_micros(100)));
        assert_eq!(p.batch_window(3, true), Some(Duration::from_micros(100)));
        assert_eq!(p.batch_window(0, true), None, "no run to grow");
        assert_eq!(p.batch_window(4, true), None, "already a full batch");
        assert_eq!(p.batch_window(1, false), None, "fusion lost the probe");
        let off = policy().with_service(4, 0);
        assert_eq!(off.batch_window(1, true), None, "window disabled by default");
        let nobatch = policy().with_service(1, 100);
        assert_eq!(nobatch.batch_window(1, true), None, "max_batch=1 never waits");
    }

    #[test]
    fn empty_dot_always_plans_inline_and_never_splits() {
        // forced thresholds that would otherwise parallelize/split
        // anything: the empty dot must still be inline (nothing to hand a
        // worker, nothing to deal into chunks)
        let p = PlanPolicy::new(0, 0, 0, vec![4, 4]);
        for acc in Accuracy::ALL {
            assert_eq!(p.plan_dot(0, acc, 0).route, DotRoute::Inline);
        }
        assert!(!p.splits(0), "an empty dot has no chunks to deal");
        assert!(serves_inline(0, 0, 8), "an empty dot has nothing to hand a worker");
        // ...while 1 byte already obeys the forced thresholds
        assert_eq!(p.plan_dot(0, Accuracy::Kahan, 1).route, DotRoute::Split);
    }

    #[test]
    fn shed_requires_a_deadline_and_fires_on_full_or_late_lanes() {
        let p = policy().with_service(16, 0).with_admission(8, 0);
        // no deadline: never shed, whatever the lane looks like
        assert_eq!(p.shed(0, 10_000, 1_000_000), None);
        // empty lane, any deadline: projected wait 0, admit
        assert_eq!(p.shed(1, 0, 1_000_000), None);
        // projected wait beyond the deadline: shed with the evidence
        let v = p.shed(100, 4, 50).expect("4 queued x 50 us >> 100 us");
        assert_eq!(v.projected_wait_us, 200);
        assert_eq!(v.queued, 4);
        assert!(!v.queue_full);
        // projected wait within the deadline: admit
        assert_eq!(p.shed(500, 4, 50), None, "200 us projected fits a 500 us deadline");
        // a full lane sheds unconditionally, even with no histogram data
        // yet (est 0): the alternative is the blocking send
        let full = p.shed(1_000_000, 8, 0).expect("full lane always sheds deadlined work");
        assert!(full.queue_full);
        // depth unknown (no with_admission): only the projection can shed
        let unknown = policy();
        assert_eq!(unknown.shed(1_000_000, usize::MAX - 1, 0), None);
    }

    /// The retry-after hint is computed from the same projection that
    /// shed the request: the excess projected wait, floored at one
    /// service time, and never 0.
    #[test]
    fn shed_verdict_carries_a_retry_after_hint() {
        let p = policy().with_service(16, 0).with_admission(8, 0);
        // projection shed: excess = 200 - 100 = 100 us, above the 50 us floor
        let v = p.shed(100, 4, 50).expect("projection shed");
        assert_eq!(v.retry_after_us, 100);
        // barely-late projection: the excess (10 us) is under one service
        // time — the floor wins (retrying before a slot frees is useless)
        let w = p.shed(240, 5, 50).expect("250 us projected > 240 us deadline");
        assert_eq!(w.retry_after_us, 50);
        // full lane with no histogram data yet: still a non-zero hint
        let full = p.shed(1_000_000, 8, 0).expect("full lane");
        assert!(full.queue_full);
        assert_eq!(full.retry_after_us, 1);
    }

    /// Synthetic calibration: a slow single shard (1 GB/s) and a fast
    /// split (10 GB/s) with no fixed cost, in every cell — route
    /// projections are then size-only, independent of the host's caches.
    fn calib(shard_gbs: f64, split_gbs: f64, fixed_us: f64) -> PlanCalibration {
        PlanCalibration {
            shard_gbs: [[shard_gbs; 3]; 2],
            split_gbs: [[split_gbs; 3]; 2],
            split_fixed_us: fixed_us,
            kahan_vs_naive: [0.5, 0.9, 0.99],
            dot2_vs_naive: [0.4, 0.8, 0.97],
        }
    }

    #[test]
    fn deadline_promotion_requires_calibration_deadline_and_fit() {
        // chunks pinned to the shard's worker count: the bit-safety gate holds
        let p = PlanPolicy::new(256 * 1024, 4 << 20, 2, vec![2, 2])
            .with_calibration(calib(1.0, 10.0, 0.0));
        let bytes = 1 << 20; // Parallel-routed; par ≈ 1049 µs, split ≈ 105 µs
        let base = p.plan_dot(0, Accuracy::Kahan, bytes);
        assert_eq!(base.route, DotRoute::Parallel);
        assert!(!base.deadline_promoted);
        // no deadline: identical to plan_dot
        let nod = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, bytes, 0);
        assert_eq!(nod.route, DotRoute::Parallel);
        // deadline between the projections: promoted
        let hit = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, bytes, 500);
        assert_eq!(hit.route, DotRoute::Split);
        assert!(hit.deadline_promoted);
        assert_eq!(hit.shard, 0, "promotion keeps the plan's shard");
        // generous deadline: the one-shard path makes it, no promotion
        let fits = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, bytes, 2_000);
        assert_eq!(fits.route, DotRoute::Parallel);
        // hopeless deadline: even the split projection blows it — serve
        // the normal route rather than burn every shard on a lost cause
        let lost = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, bytes, 50);
        assert_eq!(lost.route, DotRoute::Parallel);
        // no calibration: inert
        let bare = PlanPolicy::new(256 * 1024, 4 << 20, 2, vec![2, 2]);
        assert_eq!(
            bare.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, bytes, 500).route,
            DotRoute::Parallel
        );
        // inline and split routes never change
        let small = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, 1024, 1);
        assert_eq!(small.route, DotRoute::Inline);
        let big = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, 8 << 20, 1_000_000);
        assert_eq!(big.route, DotRoute::Split);
        assert!(!big.deadline_promoted, "a size-routed split is not a promotion");
    }

    #[test]
    fn deadline_promotion_gates_on_chunk_geometry() {
        // split_chunks 0 → chunk count 4 ≠ the shard's 2 workers: the
        // promoted split would NOT reproduce the one-shard geometry, so
        // the gate must hold the route even when the projections say go
        let p = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![2, 2])
            .with_calibration(calib(1.0, 10.0, 0.0));
        let plan = p.plan_dot_deadline(0, Accuracy::Kahan, Precision::Sp, 1 << 20, 500);
        assert_eq!(plan.route, DotRoute::Parallel, "geometry gate must veto promotion");
        assert!(!plan.deadline_promoted);
    }

    #[test]
    fn upgrade_fires_only_for_naive_with_a_free_measured_ratio() {
        let p = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![2, 2])
            .with_calibration(calib(1.0, 10.0, 0.0))
            .with_upgrade(true);
        // the synthetic ratios: L1 0.5 (costly), LLC 0.9, MEM 0.99 (free)
        // — find a byte size per class via SizeClass::of's own boundaries
        let mut by_class = [None::<u64>; 3];
        for shift in 6..30u32 {
            let b = 1u64 << shift;
            let ci = SizeClass::of(b).index();
            by_class[ci].get_or_insert(b);
        }
        let mem_bytes = by_class[2].expect("some size classifies MEM");
        let (acc, ratio) = p.upgrade_accuracy(Accuracy::Naive, mem_bytes);
        assert_eq!(acc, Accuracy::Kahan, "MEM ratio 0.99 ≥ 0.95: free upgrade");
        assert!((ratio.unwrap() - 0.99).abs() < 1e-9);
        if let Some(l1_bytes) = by_class[0] {
            let (acc, ratio) = p.upgrade_accuracy(Accuracy::Naive, l1_bytes);
            assert_eq!(acc, Accuracy::Naive, "L1 ratio 0.5 < 0.95: no upgrade");
            assert!(ratio.is_none());
        }
        // non-naive tiers always pass through
        for tier in [Accuracy::Kahan, Accuracy::Dot2, Accuracy::Exact] {
            assert_eq!(p.upgrade_accuracy(tier, mem_bytes), (tier, None));
        }
        // disabled, or no calibration: inert
        assert_eq!(
            p.clone().with_upgrade(false).upgrade_accuracy(Accuracy::Naive, mem_bytes),
            (Accuracy::Naive, None)
        );
        let bare = PlanPolicy::new(256 * 1024, 4 << 20, 0, vec![2, 2]).with_upgrade(true);
        assert_eq!(bare.upgrade_accuracy(Accuracy::Naive, mem_bytes), (Accuracy::Naive, None));
    }

    #[test]
    fn fair_admission_caps_per_client_inflight() {
        let open = policy();
        assert!(open.admits_client(0) && open.admits_client(1_000_000), "no cap = unlimited");
        let fair = policy().with_admission(64, 2);
        assert!(fair.admits_client(0));
        assert!(fair.admits_client(1));
        assert!(!fair.admits_client(2), "at the cap: shed");
        assert!(!fair.admits_client(3));
    }
}
