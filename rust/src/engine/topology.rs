//! NUMA topology discovery: which logical CPUs belong to which memory
//! domain.
//!
//! The follow-up papers (Hofmann et al., CCPE 2016; the four-generation
//! study) show Kahan-dot saturation is governed by **per-socket** memory
//! bandwidth: a multi-socket machine only streams at full speed when each
//! NUMA domain reads its own local data. The sharded engine therefore
//! needs to know the domains and their CPU lists; this module reads them
//! from `/sys/devices/system/node/node*/cpulist` and falls back to a
//! single node spanning the online CPUs when that hierarchy is absent
//! (containers, non-Linux, old kernels).
//!
//! Discovery runs once per process ([`topology_cached`]); tests and
//! benches that need a multi-shard layout on a single-node host can build
//! a synthetic split with [`Topology::fake_even`].

use std::path::Path;
use std::sync::OnceLock;

/// One NUMA domain: its sysfs id and the logical CPUs local to it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// The machine's NUMA layout (only nodes that own at least one CPU;
/// memory-only nodes are skipped because a shard needs workers to pin).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: Vec<NumaNode>,
}

impl Topology {
    /// Discover the host topology, falling back to a single node covering
    /// the online CPU set when sysfs has no NUMA hierarchy.
    pub fn detect() -> Topology {
        Self::from_sysfs(Path::new("/sys/devices/system/node"))
            .unwrap_or_else(Self::single_node)
    }

    /// Parse `node*/cpulist` under `dir`, keeping only CPUs this process
    /// may actually run on (each node's list is intersected with
    /// `allowed_cpus()` — a cgroup/taskset-restricted pod on a 2-socket
    /// host must not spawn one worker per *machine* CPU and pin them to
    /// forbidden ids). Returns `None` when the directory is missing or no
    /// node retains a usable CPU (then the single-node fallback, which is
    /// the allowed set itself, applies).
    fn from_sysfs(dir: &Path) -> Option<Topology> {
        let entries = std::fs::read_dir(dir).ok()?;
        let allowed = crate::bench::threads::allowed_cpus();
        let mut nodes: Vec<NumaNode> = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(id) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok())
            else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            let mut cpus = parse_cpu_list(&list);
            cpus.retain(|c| allowed.binary_search(c).is_ok());
            if !cpus.is_empty() {
                nodes.push(NumaNode { id, cpus });
            }
        }
        if nodes.is_empty() {
            return None;
        }
        nodes.sort_by_key(|n| n.id);
        Some(Topology { nodes })
    }

    /// One node spanning the process's allowed CPU set — the degenerate
    /// layout every single-socket host (and this container) reduces to.
    /// Uses the affinity mask rather than `0..online` so shard workers pin
    /// to pinnable ids under taskset/cgroup masks.
    pub fn single_node() -> Topology {
        Topology { nodes: vec![NumaNode { id: 0, cpus: crate::bench::threads::allowed_cpus() }] }
    }

    /// Synthetic layout for tests/benches: split the allowed CPUs into
    /// `shards` contiguous groups (each gets at least one CPU; extra
    /// shards beyond the CPU count share CPU ids round-robin so the
    /// requested shard count is always honored).
    pub fn fake_even(shards: usize) -> Topology {
        let shards = shards.max(1);
        let allowed = crate::bench::threads::allowed_cpus();
        let mut nodes = Vec::with_capacity(shards);
        if shards <= allowed.len() {
            let base = allowed.len() / shards;
            let extra = allowed.len() % shards;
            let mut start = 0;
            for id in 0..shards {
                let len = base + usize::from(id < extra);
                nodes.push(NumaNode { id, cpus: allowed[start..start + len].to_vec() });
                start += len;
            }
        } else {
            for id in 0..shards {
                nodes.push(NumaNode { id, cpus: vec![allowed[id % allowed.len()]] });
            }
        }
        Topology { nodes }
    }

    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Compact human-readable form, e.g. `node0: 0-17 | node1: 18-35`.
    pub fn render(&self) -> String {
        self.nodes
            .iter()
            .map(|n| format!("node{}: {}", n.id, render_cpu_list(&n.cpus)))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// Parse the kernel's cpulist format (`"0-3,8,10-11"`) into sorted CPU
/// ids. Malformed fields are skipped (best effort — sysfs is trusted but
/// this must never panic on a weird kernel).
pub fn parse_cpu_list(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for field in s.trim().split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        match field.split_once('-') {
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>())
                {
                    if lo <= hi && hi - lo < 4096 {
                        out.extend(lo..=hi);
                    }
                }
            }
            None => {
                if let Ok(cpu) = field.parse::<usize>() {
                    out.push(cpu);
                }
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Inverse of [`parse_cpu_list`] for display.
fn render_cpu_list(cpus: &[usize]) -> String {
    let mut parts: Vec<String> = Vec::new();
    let mut i = 0;
    while i < cpus.len() {
        let start = cpus[i];
        let mut end = start;
        while i + 1 < cpus.len() && cpus[i + 1] == end + 1 {
            i += 1;
            end = cpus[i];
        }
        parts.push(if start == end {
            format!("{start}")
        } else {
            format!("{start}-{end}")
        });
        i += 1;
    }
    parts.join(",")
}

/// The process-wide topology, discovered on first use.
pub fn topology_cached() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(Topology::detect)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kernel_cpulist_grammar() {
        assert_eq!(parse_cpu_list("0-3\n"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpu_list("0-1,4,6-7"), vec![0, 1, 4, 6, 7]);
        assert_eq!(parse_cpu_list("5"), vec![5]);
        assert_eq!(parse_cpu_list(""), Vec::<usize>::new());
        // malformed fields are skipped, not fatal
        assert_eq!(parse_cpu_list("x,2,3-1,4"), vec![2, 4]);
        // duplicates collapse
        assert_eq!(parse_cpu_list("1,1,0-2"), vec![0, 1, 2]);
    }

    #[test]
    fn render_round_trips() {
        for s in ["0-3", "0-1,4,6-7", "5", "0,2,4"] {
            let cpus = parse_cpu_list(s);
            assert_eq!(parse_cpu_list(&render_cpu_list(&cpus)), cpus, "{s}");
        }
    }

    #[test]
    fn detect_never_returns_zero_nodes() {
        let t = Topology::detect();
        assert!(!t.nodes.is_empty());
        assert!(t.total_cpus() >= 1);
        for n in &t.nodes {
            assert!(!n.cpus.is_empty(), "node{} has no CPUs", n.id);
        }
        // ids are sorted and unique
        for w in t.nodes.windows(2) {
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn missing_sysfs_falls_back_to_single_node() {
        let t = Topology::from_sysfs(Path::new("/definitely/not/a/real/sysfs"));
        assert!(t.is_none());
        let single = Topology::single_node();
        assert_eq!(single.nodes.len(), 1);
        assert_eq!(single.nodes[0].id, 0);
        assert_eq!(single.total_cpus(), single.nodes[0].cpus.len());
    }

    #[test]
    fn fake_even_covers_and_honors_shard_count() {
        for shards in [1usize, 2, 3, 7] {
            let t = Topology::fake_even(shards);
            assert_eq!(t.nodes.len(), shards);
            for n in &t.nodes {
                assert!(!n.cpus.is_empty());
            }
        }
        let allowed = crate::bench::threads::allowed_cpus().len();
        let t = Topology::fake_even(allowed);
        assert_eq!(t.total_cpus(), allowed, "even split must cover every allowed CPU");
    }

    #[test]
    fn cached_topology_is_stable() {
        let a = topology_cached() as *const Topology;
        let b = topology_cached() as *const Topology;
        assert_eq!(a, b);
    }
}
