//! NUMA-sharded serving tier: one persistent [`DotEngine`] per memory
//! domain, a locality-aware router in front, and a cross-shard compensated
//! merge behind.
//!
//! Per Hofmann et al. (CCPE 2016) and the four-generation study, a
//! multi-socket machine only serves dots at full speed when every NUMA
//! domain streams its own local data — remote-socket traffic halves
//! effective bandwidth. So each shard owns a private [`BufferPool`] (its
//! recycled buffers stay resident in its domain) and a private
//! `WorkerPool` pinned to that domain's CPU list (exact sysfs ids, not
//! naive CPU `i`).
//!
//! Routing:
//! * **pooled streams** ([`HomedSlice`]) remember the shard that admitted
//!   them and always execute there (data is already local); pairs that
//!   will be dotted together should co-locate via `admit_to_*`;
//! * **fresh requests** round-robin across shards;
//! * the serving tier (`coordinator::service`) layers a router *pool* on
//!   top: one submitter thread per shard, fed by a bounded queue, calling
//!   straight into that shard's engine — request-level parallelism across
//!   shards without a central router thread;
//! * **very large dots** (≥ `split_min_bytes`) split across *all* shards:
//!   the request is cut once into globally balanced cache-line-aligned
//!   chunks, contiguous chunk blocks go to each shard weighted by its
//!   worker count (one admission copy per block, executed **on a worker
//!   of that shard** so fresh pages first-touch in-domain), and every
//!   per-chunk partial merges with the **same** compensated (Neumaier)
//!   fold the single-engine chunk merge uses, in global chunk order.
//!
//! Accuracy & determinism: because the cross-shard merge is the flat
//! compensated fold over the *global* chunk partials (not a fold of
//! per-shard folds), the sequential Kahan bound `O(u)·Σ|aᵢbᵢ|` survives
//! the extra reduction level, and for a fixed chunk geometry the result
//! is bit-identical whether 1 or N shards execute it — property-tested in
//! `rust/tests/test_engine.rs`.
//!
//! On a single-node host (this container included) [`ShardedEngine`]
//! degrades to exactly one shard and delegates straight to its
//! [`DotEngine`], bit-identical to an unsharded engine of the same
//! configuration.

use super::parallel::{chunk_ranges, collect_partials, panic_message};
use super::plan::{DotRoute, PlanPolicy};
use super::pool::{PoolStats, PooledSlice};
use super::topology::{topology_cached, Topology};
use super::{
    exec_batch_f32, exec_batch_f64, kernel_for_f32, kernel_for_f64, DotEngine, EngineConfig,
    EngineStats,
};
use crate::bench::kernels::{compensated_fold_f32, compensated_fold_f64};
use crate::isa::{Accuracy, Precision};
use crate::util::faults;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};

/// The no-profile fallback split threshold (4 MiB total working set).
/// This is the ONE place the old hardcoded constant survives: when no
/// calibration profile has loaded ([`crate::engine::profile::host_profile`]),
/// auto-resolution (`split_min_bytes == 0`) falls back here. With a profile
/// present the threshold is the measured crossover
/// ([`crate::engine::profile::CalibrationProfile::derived_split_min_bytes`])
/// — the point where cross-shard bandwidth actually beats one shard plus
/// the measured per-request fan-out cost.
pub const DEFAULT_SPLIT_MIN_BYTES: usize = 4 << 20;

/// Sharded-tier configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardedConfig {
    /// per-shard engine config; `threads == 0` means one worker per CPU of
    /// the shard's NUMA domain
    pub engine: EngineConfig,
    /// total working set (both streams, bytes) at which a fresh dot is
    /// split across every shard instead of routed to one. `0` = auto:
    /// derive from the calibration profile's measured crossover, falling
    /// back to [`DEFAULT_SPLIT_MIN_BYTES`] when no profile loaded. Tests
    /// and benches pin explicit values so routing never depends on the
    /// host the suite runs on.
    pub split_min_bytes: usize,
    /// global chunk count for split dots; 0 = total workers across shards.
    /// Fixing this fixes the chunk geometry, making results bit-identical
    /// for any shard count.
    pub chunks: usize,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            engine: EngineConfig::default(),
            split_min_bytes: 0, // auto: profile-derived, else DEFAULT_SPLIT_MIN_BYTES
            chunks: 0,
        }
    }
}

/// A pooled stream plus the shard that admitted it (its NUMA home). Dots
/// over homed slices execute on the home shard of their first operand.
#[derive(Clone)]
pub struct HomedSlice<T: Copy> {
    pub shard: usize,
    pub slice: Arc<PooledSlice<T>>,
}

impl<T: Copy> HomedSlice<T> {
    pub fn len(&self) -> usize {
        self.slice.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slice.is_empty()
    }
}

/// Aggregate counters across every shard.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardedStats {
    pub shards: usize,
    /// dots served (per-shard requests plus split dots, which execute
    /// across shards but count once)
    pub requests: u64,
    /// dots that took a chunked-parallel path inside one shard engine
    pub parallel: u64,
    /// dots served through a batched execution path (see the engine
    /// module's "Batching invariant") — a subset of `requests`
    pub batched: u64,
    /// dots served by the split path (cut on global chunk boundaries over
    /// the whole shard set; on a single-shard host this is the same
    /// chunked reduction, still counted here because it bypasses the
    /// shard engine's own counters)
    pub split_dots: u64,
    /// split-path dots promoted to Split by deadline-aware routing (the
    /// calibrated projection said the one-shard parallel path would blow
    /// the request's deadline and the split path fits) — a subset of
    /// `split_dots`; route changed, bits identical
    pub deadline_splits: u64,
    /// dots whose fan-out the ECM governance layer capped below the
    /// realized worker count — per-shard engine caps plus split-path dots
    /// where at least one shard ran on a capped worker subset
    pub capped_requests: u64,
    pub pool: PoolStats,
    pub pin_failures: u64,
    /// worker threads replaced by supervision sweeps across all shards
    /// (dead or wedged workers respawned onto the same queue — see the
    /// engine module's fault-domain layer)
    pub respawns: u64,
    /// pin failures from those respawns, counted separately from the
    /// startup `pin_failures` so a degraded host is visible as such
    pub respawn_pin_failures: u64,
}

/// The multi-socket serving tier: one pinned engine per NUMA domain.
pub struct ShardedEngine {
    shards: Vec<DotEngine>,
    cfg: ShardedConfig,
    /// the compiled routing policy: every route/split threshold decision
    /// below goes through this planner, never through raw `cfg` reads
    policy: PlanPolicy,
    /// provenance of the resolved split threshold (explicit / measured /
    /// default) — surfaced by `repro plan`
    split_src: &'static str,
    next: AtomicUsize,
    split_dots: AtomicU64,
    /// split-path dots that only split because a deadline promoted them
    /// (the planner's projection said Parallel would blow the deadline and
    /// Split fits) — a subset of `split_dots`
    deadline_splits: AtomicU64,
    /// split-path dots where governance capped at least one shard's
    /// chunk-block onto a worker subset (the per-shard engines count
    /// their own capped parallel dots)
    split_capped: AtomicU64,
    /// per-shard quarantine bits, set by the service supervisor when a
    /// shard exhausts its respawn budget. A quarantined shard is skipped
    /// by fresh routing and weighted out of split chunk-block assignment
    /// (`split_blocks_masked`) — but the chunk geometry, kernel choice
    /// and merge order never change, so quarantine never changes bits.
    quarantined: Vec<AtomicBool>,
}

macro_rules! sharded_dot_impl {
    ($dot:ident, $dot_on:ident, $dot_on_deadline:ident, $dot_homed:ident, $admit:ident, $admit_to:ident, $split:ident,
     $dot_batch:ident, $dot_batch_on:ident, $dot_batch_homed:ident, $admit_many_to:ident,
     $engine_dot:ident, $engine_dot_pooled:ident, $engine_admit:ident, $engine_dot_batch:ident,
     $engine_admit_many:ident, $exec_batch:ident, $kernel_for:ident,
     $fold:ident, $prec:expr, $ty:ty, $elems_per_cl:expr) => {
        /// Serve one dot: single-shard hosts and sub-split sizes route to
        /// one shard round-robin; very large dots split across all shards.
        /// Length policy as for [`DotEngine`] (see the engine module doc).
        /// (The round-robin cursor also advances on split-path dots, which
        /// ignore it — harmless, and it keeps every threshold decision in
        /// the preferred-shard method below.)
        pub fn $dot(&self, accuracy: Accuracy, a: &[$ty], b: &[$ty]) -> $ty {
            self.$dot_on(self.route(), accuracy, a, b)
        }

        /// Like the round-robin dot, but with the sub-split shard chosen
        /// by the caller (clamped) — the service's router lanes use this
        /// so the shard decided at routing time and the shard that
        /// executes are the same one, while the split-vs-route threshold
        /// stays compiled by the planner (`self.policy`, the engine
        /// tier's [`crate::engine::PlanPolicy`]). Very large dots still split
        /// across every shard: on a single shard with default `chunks`
        /// the split path degenerates to exactly the per-engine chunked
        /// reduction (same geometry, same fold, same bits), so 1-vs-N
        /// sharding stays bit-identical.
        pub fn $dot_on(&self, shard: usize, accuracy: Accuracy, a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(
                a.len(),
                b.len(),
                "sharded dot called with mismatched stream lengths (see engine length policy)"
            );
            let n = a.len().min(b.len());
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            match self.policy.plan_dot(shard, accuracy, total_bytes).route {
                DotRoute::Split => self.$split(accuracy, &a[..n], &b[..n]),
                // Inline vs Parallel is the engine's half of the same
                // policy — it re-derives the identical plan from the
                // shared predicate
                _ => self.shards[self.policy.clamp_shard(shard)].$engine_dot(
                    accuracy,
                    &a[..n],
                    &b[..n],
                ),
            }
        }

        /// Like the preferred-shard dot, but carrying the request's
        /// deadline into the planner: when the calibrated projection says
        /// the one-shard parallel path would blow `deadline_us` and the
        /// cross-shard split path fits, the plan promotes to Split
        /// ([`crate::engine::PlanPolicy::plan_dot_deadline`]). The promotion is
        /// gated on identical chunk geometry, so the served bits are the
        /// ones the un-promoted route would have produced — only the
        /// latency changes. `deadline_us == 0` (no deadline) and hosts
        /// without a calibration profile degrade to exactly `dot_on`.
        pub fn $dot_on_deadline(
            &self,
            shard: usize,
            accuracy: Accuracy,
            deadline_us: u64,
            a: &[$ty],
            b: &[$ty],
        ) -> $ty {
            debug_assert_eq!(
                a.len(),
                b.len(),
                "sharded dot called with mismatched stream lengths (see engine length policy)"
            );
            let n = a.len().min(b.len());
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            let plan =
                self.policy.plan_dot_deadline(shard, accuracy, $prec, total_bytes, deadline_us);
            if plan.deadline_promoted {
                self.deadline_splits.fetch_add(1, Ordering::Relaxed);
            }
            match plan.route {
                DotRoute::Split => self.$split(accuracy, &a[..n], &b[..n]),
                _ => self.shards[plan.shard].$engine_dot(accuracy, &a[..n], &b[..n]),
            }
        }

        /// Split one dot across every shard on global chunk boundaries and
        /// merge all per-chunk partials with the compensated fold in
        /// global chunk order (the same fold, one more reduction level).
        fn $split(&self, accuracy: Accuracy, a: &[$ty], b: &[$ty]) -> $ty {
            let n = a.len();
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            // select the kernel ONCE for the full request size: every
            // shard must run the same kernel for bit-determinism
            let f = $kernel_for(accuracy, total_bytes);
            let ranges = chunk_ranges(n, self.policy.split_chunk_count(), $elems_per_cl);
            if ranges.len() <= 1 {
                let s = self.route();
                return self.shards[s].$engine_dot(accuracy, a, b);
            }
            // every split-path dot is counted here (it never reaches a
            // shard engine's own `requests` counter) — including on a
            // single-shard host, where the split path degenerates to the
            // ordinary chunked reduction but must still show up in stats
            self.split_dots.fetch_add(1, Ordering::Relaxed);
            // the weighted chunk-block assignment is compiled by the
            // planner (contiguous blocks per shard, weighted by worker
            // count, deterministic cumulative rounding — the assignment
            // can never change the partials or the fold). Quarantined
            // shards are weighted out here; the chunk geometry above
            // stays fixed, so the partials and merge order are identical
            // whichever shards execute them.
            let blocks = self
                .policy
                .split_blocks_masked(ranges.len(), &self.healthy_mask());
            let (tx, rx) = mpsc::channel::<(usize, Result<$ty, String>)>();
            let mut any_capped = false;
            for &(s, clo, chi) in &blocks {
                let span_lo = ranges[clo].0;
                let span_hi = ranges[chi - 1].1;
                // worker-side admission: the copy runs on a worker pinned
                // inside shard `s`, so fresh pages first-touch in-domain
                let pa = self.shards[s].$engine_admit(&a[span_lo..span_hi]);
                let pb = self.shards[s].$engine_admit(&b[span_lo..span_hi]);
                // governance: the shard's chunk-block keeps its planner
                // geometry but lands on a rotated worker SUBSET when the
                // ECM cap binds — the freed workers stay available to
                // other lanes' concurrent requests
                let shard_workers = self.shards[s].threads();
                let cap = self.shards[s].worker_cap($prec, total_bytes);
                let slots = cap.min(chi - clo).min(shard_workers).max(1);
                let base = if slots < shard_workers {
                    self.shards[s].workers().subset_start(slots)
                } else {
                    0
                };
                if cap < shard_workers {
                    any_capped = true;
                }
                for (w, ci) in (clo..chi).enumerate() {
                    let (lo, hi) = (ranges[ci].0 - span_lo, ranges[ci].1 - span_lo);
                    let pa = Arc::clone(&pa);
                    let pb = Arc::clone(&pb);
                    let tx = tx.clone();
                    self.shards[s].workers().submit_to(
                        base + (w % slots),
                        Box::new(move || {
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                if faults::act(faults::check("split_chunk", ci)) {
                                    panic!("faultinject: split chunk {ci} killed");
                                }
                                f(&pa.as_slice()[lo..hi], &pb.as_slice()[lo..hi])
                            }));
                            let _ = tx.send((ci, r.map_err(panic_message)));
                        }),
                    );
                }
            }
            if any_capped {
                self.split_capped.fetch_add(1, Ordering::Relaxed);
            }
            drop(tx);
            let sums = collect_partials(rx, ranges.len(), stringify!($split));
            let comps = vec![0.0 as $ty; sums.len()];
            $fold(&sums, &comps)
        }

        /// Admit a stream into the next shard round-robin; the returned
        /// handle remembers its home shard for every later dot. Streams
        /// that will be dotted against each other should co-locate via
        /// [`ShardedEngine::admit_to_f32`]/`admit_to_f64` instead —
        /// round-robin placement puts a back-to-back admitted pair on
        /// *different* shards, and every later dot over the pair then
        /// streams one operand from a remote domain.
        pub fn $admit(&self, v: &[$ty]) -> HomedSlice<$ty> {
            let shard = self.route();
            self.$admit_to(shard, v)
        }

        /// Admit a stream onto an explicit shard (clamped), e.g. the home
        /// shard of the stream it will be dotted against. The copy runs on
        /// one of that shard's pinned workers so fresh pages first-touch
        /// in-domain.
        pub fn $admit_to(&self, shard: usize, v: &[$ty]) -> HomedSlice<$ty> {
            let shard = shard % self.shards.len();
            HomedSlice { shard, slice: self.shards[shard].$engine_admit(v) }
        }

        /// Zero-copy steady state: execute on the home shard of `a`
        /// (admission locality — the data is already in that domain).
        pub fn $dot_homed(
            &self,
            accuracy: Accuracy,
            a: &HomedSlice<$ty>,
            b: &HomedSlice<$ty>,
        ) -> $ty {
            let s = a.shard.min(self.shards.len() - 1);
            self.shards[s].$engine_dot_pooled(accuracy, &a.slice, &b.slice)
        }

        /// Admit several streams onto one shard (clamped) in a single
        /// worker pass — one handoff and one in-domain first-touch copy
        /// loop instead of one round trip per stream. This is the
        /// admission-burst coalescing primitive behind the service's
        /// `Admit`/`AdmitPair` batching.
        pub fn $admit_many_to(&self, shard: usize, vs: &[&[$ty]]) -> Vec<HomedSlice<$ty>> {
            let shard = shard % self.shards.len();
            self.shards[shard]
                .$engine_admit_many(vs)
                .into_iter()
                .map(|slice| HomedSlice { shard, slice })
                .collect()
        }

        /// Serve a batch on ONE shard — the service lane's coalescing
        /// call. Requests below the split threshold execute on shard `s`
        /// as one engine batch; larger ones take the unchanged cross-shard
        /// split path one by one. Bit-identical to per-request `dot_on`
        /// calls (the engine module's batching invariant). Runs on the
        /// caller's thread — call from a submitter, never from a worker.
        pub fn $dot_batch_on(
            &self,
            shard: usize,
            accuracy: Accuracy,
            reqs: &[(&[$ty], &[$ty])],
        ) -> Vec<$ty> {
            let s = shard % self.shards.len();
            let mut out = vec![0.0 as $ty; reqs.len()];
            let mut small_idx: Vec<usize> = Vec::with_capacity(reqs.len());
            let mut smalls: Vec<(&[$ty], &[$ty])> = Vec::with_capacity(reqs.len());
            for (i, &(a, b)) in reqs.iter().enumerate() {
                let n = a.len().min(b.len());
                let total = (2 * n * std::mem::size_of::<$ty>()) as u64;
                if self.policy.splits(total) {
                    out[i] = self.$dot_on(s, accuracy, a, b);
                } else {
                    small_idx.push(i);
                    smalls.push((&a[..n], &b[..n]));
                }
            }
            if !smalls.is_empty() {
                let vals = self.shards[s].$engine_dot_batch(accuracy, &smalls);
                for (i, v) in small_idx.into_iter().zip(vals) {
                    out[i] = v;
                }
            }
            out
        }

        /// Serve a batch across the whole shard set: every small request
        /// is dealt a shard round-robin (exactly as serial submission
        /// would deal them) and each shard's group executes CONCURRENTLY
        /// as one worker-job batch on that shard; requests at or above the
        /// split threshold take the unchanged cross-shard split path, and
        /// mid-size requests (chunked-parallel inside one shard) the
        /// unchanged per-request route. Bit-identical to the serial loop.
        /// Must not be called from a shard worker.
        pub fn $dot_batch(&self, accuracy: Accuracy, reqs: &[(&[$ty], &[$ty])]) -> Vec<$ty> {
            let mut out = vec![0.0 as $ty; reqs.len()];
            let mut per_shard: Vec<Vec<(usize, &[$ty], &[$ty])>> =
                (0..self.shards.len()).map(|_| Vec::new()).collect();
            let mut splits: Vec<(usize, usize)> = Vec::new();
            let mut mids: Vec<(usize, usize)> = Vec::new();
            for (i, &(a, b)) in reqs.iter().enumerate() {
                let n = a.len().min(b.len());
                if n == 0 {
                    // zero-length dot: `out[i]` is already +0.0 — resolved
                    // here, never dispatched to a shard worker group (see
                    // the engine module's zero-length guards)
                    self.shards[self.policy.clamp_shard(self.route())].note_request();
                    continue;
                }
                let total = (2 * n * std::mem::size_of::<$ty>()) as u64;
                let plan = self.policy.plan_dot(self.route(), accuracy, total);
                match plan.route {
                    DotRoute::Split => splits.push((i, plan.shard)),
                    DotRoute::Inline => per_shard[plan.shard].push((i, &a[..n], &b[..n])),
                    DotRoute::Parallel => mids.push((i, plan.shard)),
                }
            }
            let (tx, rx) = mpsc::channel();
            let mut dispatched = 0usize;
            for (s, group) in per_shard.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                dispatched += group.len();
                self.shards[s].note_batch(group.len());
                let raw: Vec<(usize, usize, usize, usize)> = group
                    .iter()
                    .map(|&(i, a, b)| (i, a.as_ptr() as usize, b.as_ptr() as usize, a.len()))
                    .collect();
                let tx = tx.clone();
                self.shards[s].workers().submit(Box::new(move || {
                    // SAFETY: the caller blocks on `rx` below until every
                    // group has reported, so the borrows behind the raw
                    // pointers outlive every reconstructed slice
                    let items: Vec<(usize, &[$ty], &[$ty])> = raw
                        .iter()
                        .map(|&(i, pa, pb, n)| unsafe {
                            (
                                i,
                                std::slice::from_raw_parts(pa as *const $ty, n),
                                std::slice::from_raw_parts(pb as *const $ty, n),
                            )
                        })
                        .collect();
                    $exec_batch(accuracy, &items, &tx);
                }));
            }
            drop(tx);
            // splits and mid-size requests run on this thread while the
            // shard groups execute concurrently
            for &(i, s) in &splits {
                let (a, b) = reqs[i];
                out[i] = self.$dot_on(s, accuracy, a, b);
            }
            for &(i, s) in &mids {
                let (a, b) = reqs[i];
                out[i] = self.shards[s].$engine_dot(accuracy, a, b);
            }
            let mut got = 0usize;
            for (i, r) in rx {
                out[i] = r.unwrap_or_else(|m| {
                    panic!("{}: request {i} panicked: {m}", stringify!($dot_batch))
                });
                got += 1;
            }
            assert_eq!(
                got,
                dispatched,
                "{}: a shard batch group reported no result (worker died)",
                stringify!($dot_batch)
            );
            out
        }

        /// Zero-copy steady-state batch: dot pairs of already-admitted
        /// streams, grouped by the home shard of each pair's first operand
        /// and executed concurrently as one worker-job batch per shard —
        /// bit-identical to per-request `dot_homed` calls. Pairs big
        /// enough for a shard's chunked-parallel path take the per-request
        /// route. Must not be called from a shard worker.
        pub fn $dot_batch_homed(
            &self,
            accuracy: Accuracy,
            reqs: &[(&HomedSlice<$ty>, &HomedSlice<$ty>)],
        ) -> Vec<$ty> {
            let mut out = vec![0.0 as $ty; reqs.len()];
            let mut per_shard: Vec<Vec<(usize, &[$ty], &[$ty])>> =
                (0..self.shards.len()).map(|_| Vec::new()).collect();
            let mut bigs: Vec<(usize, usize)> = Vec::new();
            for (i, &(a, b)) in reqs.iter().enumerate() {
                let s = a.shard.min(self.shards.len() - 1);
                let n = a.len().min(b.len());
                if n == 0 {
                    // zero-length dot: `out[i]` is already +0.0, no
                    // worker group (see the engine module's guards)
                    self.shards[s].note_request();
                    continue;
                }
                let total = (2 * n * std::mem::size_of::<$ty>()) as u64;
                if self.policy.serves_inline_on(s, total) {
                    per_shard[s].push((i, &a.slice.as_slice()[..n], &b.slice.as_slice()[..n]));
                } else {
                    bigs.push((i, s));
                }
            }
            let (tx, rx) = mpsc::channel();
            let mut dispatched = 0usize;
            for (s, group) in per_shard.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                dispatched += group.len();
                self.shards[s].note_batch(group.len());
                let raw: Vec<(usize, usize, usize, usize)> = group
                    .iter()
                    .map(|&(i, a, b)| (i, a.as_ptr() as usize, b.as_ptr() as usize, a.len()))
                    .collect();
                let tx = tx.clone();
                self.shards[s].workers().submit(Box::new(move || {
                    // SAFETY: the caller holds the `HomedSlice` refs in
                    // `reqs` and blocks on `rx` until every group reports,
                    // so the pooled buffers outlive the reconstructed
                    // slices
                    let items: Vec<(usize, &[$ty], &[$ty])> = raw
                        .iter()
                        .map(|&(i, pa, pb, n)| unsafe {
                            (
                                i,
                                std::slice::from_raw_parts(pa as *const $ty, n),
                                std::slice::from_raw_parts(pb as *const $ty, n),
                            )
                        })
                        .collect();
                    $exec_batch(accuracy, &items, &tx);
                }));
            }
            drop(tx);
            for &(i, s) in &bigs {
                let (a, b) = reqs[i];
                out[i] = self.shards[s].$engine_dot_pooled(accuracy, &a.slice, &b.slice);
            }
            let mut got = 0usize;
            for (i, r) in rx {
                out[i] = r.unwrap_or_else(|m| {
                    panic!("{}: request {i} panicked: {m}", stringify!($dot_batch_homed))
                });
                got += 1;
            }
            assert_eq!(
                got,
                dispatched,
                "{}: a shard batch group reported no result (worker died)",
                stringify!($dot_batch_homed)
            );
            out
        }
    };
}

impl ShardedEngine {
    /// One shard per discovered NUMA domain (single shard when the host
    /// has no NUMA hierarchy).
    pub fn new(cfg: ShardedConfig) -> ShardedEngine {
        Self::from_topology(topology_cached(), cfg)
    }

    /// Build shards for an explicit topology (tests and benches use
    /// [`Topology::fake_even`] to exercise multi-shard layouts on
    /// single-node hosts).
    pub fn from_topology(topo: &Topology, cfg: ShardedConfig) -> ShardedEngine {
        assert!(!topo.nodes.is_empty(), "topology must have at least one node");
        let mut cfg = cfg;
        let shards: Vec<DotEngine> = topo
            .nodes
            .iter()
            .map(|node| DotEngine::new_on(cfg.engine, &node.cpus))
            .collect();
        let threads_vec: Vec<usize> = shards.iter().map(|s| s.threads()).collect();
        // resolve the split threshold: 0 = auto — the calibration
        // profile's measured crossover when one loaded, else the
        // documented DEFAULT_SPLIT_MIN_BYTES fallback. Explicit nonzero
        // values (every test/bench) pass through untouched.
        let profile = super::profile::host_profile();
        let split_src = if cfg.split_min_bytes != 0 {
            "explicit (config)"
        } else {
            match profile.and_then(|p| p.derived_split_min_bytes(&threads_vec)) {
                Some(b) => {
                    cfg.split_min_bytes = b as usize;
                    "measured (calibration profile crossover)"
                }
                None => {
                    cfg.split_min_bytes = DEFAULT_SPLIT_MIN_BYTES;
                    "default (no calibration profile)"
                }
            }
        };
        // compile the policy AFTER the shards exist: per-shard worker
        // counts are only known once `threads == 0` has been resolved
        let policy = PlanPolicy::new(
            cfg.engine.parallel_cutoff_bytes,
            cfg.split_min_bytes,
            cfg.chunks,
            threads_vec.clone(),
        );
        // governance: the compiled policy carries the host ECM verdict's
        // worker caps so every consumer (split path, service, CLI) sees
        // the same governed view the shard engines enforce internally
        let policy = if cfg.engine.governance {
            policy.with_governance(crate::ecm::governance::host_verdict().worker_caps())
        } else {
            policy
        };
        // a loaded profile also arms the planner's projection tables
        // (deadline-aware routing, free-upgrade ratios) — thresholds and
        // routing only, never chunk geometry or bits
        let policy = match profile {
            Some(p) => policy.with_calibration(p.plan_calibration(&threads_vec)),
            None => policy,
        };
        let quarantined = shards.iter().map(|_| AtomicBool::new(false)).collect();
        ShardedEngine {
            shards,
            cfg,
            policy,
            split_src,
            next: AtomicUsize::new(0),
            split_dots: AtomicU64::new(0),
            deadline_splits: AtomicU64::new(0),
            split_capped: AtomicU64::new(0),
            quarantined,
        }
    }

    /// Where the resolved `split_min_bytes` came from: explicit config, the
    /// calibration profile's measured crossover, or the no-profile default
    /// — printed by `repro plan` as the threshold's provenance.
    pub fn split_min_source(&self) -> &'static str {
        self.split_src
    }

    /// Install (or replace) the planner's calibration projections —
    /// bench scenarios and property tests pin synthetic profiles here so
    /// deadline-routing behavior doesn't depend on the host. Routing only:
    /// a calibration can change which route serves a request, never the
    /// chunk geometry or the bits of the result.
    pub fn set_calibration(&mut self, calibration: super::plan::PlanCalibration) {
        self.policy = self.policy.clone().with_calibration(calibration);
    }

    /// Override the governance caps on the compiled policy AND every shard
    /// engine (`[precision][size class]`, `usize::MAX` = uncapped) — see
    /// [`DotEngine::set_worker_caps`]. Bench saturation sweeps and
    /// property tests pin explicit caps here so capped-vs-uncapped
    /// comparisons don't depend on the host the suite runs on.
    pub fn set_worker_caps(&mut self, caps: [[usize; 3]; 2]) {
        self.policy = self.policy.clone().with_governance(caps);
        for sh in &mut self.shards {
            sh.set_worker_caps(caps);
        }
    }

    /// The process-wide sharded engine (used by the service's host
    /// backend).
    pub fn global() -> &'static ShardedEngine {
        static ENGINE: OnceLock<ShardedEngine> = OnceLock::new();
        ENGINE.get_or_init(|| ShardedEngine::new(ShardedConfig::default()))
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &DotEngine {
        &self.shards[i]
    }

    pub fn config(&self) -> &ShardedConfig {
        &self.cfg
    }

    /// The engine tier's compiled routing policy (thresholds + realized
    /// per-shard worker counts). The service clones it and layers its
    /// batching knobs on via [`PlanPolicy::with_service`]; the `repro
    /// plan` CLI prints it.
    pub fn policy(&self) -> &PlanPolicy {
        &self.policy
    }

    pub fn total_workers(&self) -> usize {
        self.shards.iter().map(|s| s.threads()).sum()
    }

    /// Round-robin shard for a fresh (un-homed) request, skipping
    /// quarantined shards. When every shard is quarantined the mask is
    /// ignored (serving degraded beats serving nothing) and plain
    /// round-robin resumes.
    fn route(&self) -> usize {
        let n = self.shards.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let s = (start + off) % n;
            if !self.quarantined[s].load(Ordering::Relaxed) {
                return s;
            }
        }
        start % n
    }

    /// Drop a shard from fresh routing and split-path chunk-block
    /// assignment. Bits never change: the chunk geometry and merge order
    /// come from `split_chunk_count`, which counts ALL shards' workers.
    pub fn quarantine(&self, shard: usize) {
        if shard < self.quarantined.len() {
            self.quarantined[shard].store(true, Ordering::Relaxed);
        }
    }

    /// Return a quarantined shard to service (the supervisor calls this
    /// after a successful probe dot).
    pub fn reinstate(&self, shard: usize) {
        if shard < self.quarantined.len() {
            self.quarantined[shard].store(false, Ordering::Relaxed);
        }
    }

    pub fn is_quarantined(&self, shard: usize) -> bool {
        shard < self.quarantined.len() && self.quarantined[shard].load(Ordering::Relaxed)
    }

    /// Per-shard health mask for the split path's weighted chunk-block
    /// assignment (`true` = healthy).
    fn healthy_mask(&self) -> Vec<bool> {
        self.quarantined
            .iter()
            .map(|q| !q.load(Ordering::Relaxed))
            .collect()
    }

    /// Sweep every shard's worker pool once: respawn dead workers, and —
    /// when `wedge_us > 0` — workers whose heartbeat shows a job running
    /// longer than the threshold. Returns the number of workers replaced.
    pub fn supervise(&self, wedge_us: u64) -> usize {
        self.shards.iter().map(|s| s.supervise(wedge_us)).sum()
    }

    /// Per-shard engine counters, indexed by shard — the observability
    /// hook behind `repro engine-info` and the service-concurrency tests
    /// (which assert that concurrently submitted requests actually landed
    /// on more than one shard).
    pub fn stats_per_shard(&self) -> Vec<EngineStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    pub fn stats(&self) -> ShardedStats {
        let mut st = ShardedStats {
            shards: self.shards.len(),
            split_dots: self.split_dots.load(Ordering::Relaxed),
            deadline_splits: self.deadline_splits.load(Ordering::Relaxed),
            capped_requests: self.split_capped.load(Ordering::Relaxed),
            ..ShardedStats::default()
        };
        for sh in &self.shards {
            let e = sh.stats();
            st.requests += e.requests;
            st.parallel += e.parallel;
            st.batched += e.batched;
            st.capped_requests += e.capped_requests;
            st.pool.hits += e.pool.hits;
            st.pool.misses += e.pool.misses;
            st.pool.returned += e.pool.returned;
            st.pin_failures += e.pin_failures;
            st.respawns += e.respawns;
            st.respawn_pin_failures += e.respawn_pin_failures;
        }
        st.requests += st.split_dots;
        st
    }

    sharded_dot_impl!(
        dot_f32,
        dot_on_f32,
        dot_on_deadline_f32,
        dot_homed_f32,
        admit_f32,
        admit_to_f32,
        split_dot_f32,
        dot_batch_f32,
        dot_batch_on_f32,
        dot_batch_homed_f32,
        admit_many_to_f32,
        dot_f32,
        dot_pooled_f32,
        admit_local_f32,
        dot_batch_f32,
        admit_local_many_f32,
        exec_batch_f32,
        kernel_for_f32,
        compensated_fold_f32,
        Precision::Sp,
        f32,
        16
    );
    sharded_dot_impl!(
        dot_f64,
        dot_on_f64,
        dot_on_deadline_f64,
        dot_homed_f64,
        admit_f64,
        admit_to_f64,
        split_dot_f64,
        dot_batch_f64,
        dot_batch_on_f64,
        dot_batch_homed_f64,
        admit_many_to_f64,
        dot_f64,
        dot_pooled_f64,
        admit_local_f64,
        dot_batch_f64,
        admit_local_many_f64,
        exec_batch_f64,
        kernel_for_f64,
        compensated_fold_f64,
        Precision::Dp,
        f64,
        8
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    fn cfg(threads: usize, split_min_bytes: usize, chunks: usize) -> ShardedConfig {
        ShardedConfig {
            engine: EngineConfig { threads, ..EngineConfig::default() },
            split_min_bytes,
            chunks,
        }
    }

    #[test]
    fn single_node_degrades_to_one_shard_bit_identical_to_dot_engine() {
        let sharded =
            ShardedEngine::from_topology(&Topology::single_node(), cfg(2, DEFAULT_SPLIT_MIN_BYTES, 0));
        assert_eq!(sharded.shards(), 1);
        let plain = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
        let mut rng = Rng::new(41);
        // inline path, chunked-parallel path, and above-split-threshold
        // path (1 << 20 elements = 8 MB total ≥ the 4 MB threshold)
        for n in [1000usize, 300_000, 1 << 20] {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let s = sharded.dot_f32(Accuracy::Kahan, &a, &b);
            let p = plain.dot_f32(Accuracy::Kahan, &a, &b);
            assert_eq!(s.to_bits(), p.to_bits(), "n={n}");
        }
        // the one above-threshold dot took the (degenerate) split path and
        // must be visible in stats; the two routed dots count on the shard
        let st = sharded.stats();
        assert_eq!(st.split_dots, 1, "{st:?}");
        assert_eq!(st.requests, 3, "routed + split dots must all be counted: {st:?}");
    }

    #[test]
    fn split_dot_matches_exact_across_fake_shards() {
        let sharded = ShardedEngine::from_topology(&Topology::fake_even(2), cfg(1, 64 << 10, 0));
        assert_eq!(sharded.shards(), 2);
        let mut rng = Rng::new(43);
        let n = 100_000; // 800 KB total >> 64 KB split threshold
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&a, &b);
        let scale: f64 =
            a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
        let got = sharded.dot_f32(Accuracy::Kahan, &a, &b) as f64;
        assert!((got - exact).abs() / scale < 1e-6, "{got} vs {exact}");
        let st = sharded.stats();
        assert_eq!(st.split_dots, 1, "{st:?}");
    }

    #[test]
    fn homed_streams_execute_on_their_admission_shard() {
        let sharded =
            ShardedEngine::from_topology(&Topology::fake_even(3), cfg(1, DEFAULT_SPLIT_MIN_BYTES, 0));
        let mut rng = Rng::new(47);
        let n = 4096;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
        let a = sharded.admit_f32(&av);
        let b = sharded.admit_f32(&bv);
        assert!(a.shard < sharded.shards());
        let before = sharded.shard(a.shard).stats().requests;
        let got = sharded.dot_homed_f32(Accuracy::Kahan, &a, &b) as f64;
        assert!((got - exact).abs() / scale < 1e-6);
        let after = sharded.shard(a.shard).stats().requests;
        assert_eq!(after, before + 1, "dot must run on the home shard of `a`");

        // co-located admission: the partner stream lands on a's shard, so
        // the steady-state pair never crosses a domain
        let b2 = sharded.admit_to_f32(a.shard, &bv);
        assert_eq!(b2.shard, a.shard);
        let got2 = sharded.dot_homed_f32(Accuracy::Kahan, &a, &b2) as f64;
        assert!((got2 - exact).abs() / scale < 1e-6);
    }

    #[test]
    fn f64_split_path_matches_exact() {
        use crate::accuracy::exact::exact_dot_f64;
        let sharded = ShardedEngine::from_topology(&Topology::fake_even(2), cfg(1, 64 << 10, 0));
        let mut rng = Rng::new(53);
        let n = 50_000; // 800 KB total
        let a = rng.normal_f64_vec(n);
        let b = rng.normal_f64_vec(n);
        let exact = exact_dot_f64(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1e-300);
        let got = sharded.dot_f64(Accuracy::Kahan, &a, &b);
        assert!((got - exact).abs() / scale < 1e-14);
    }

    /// Governance at the split layer: capping every shard to one worker
    /// changes nothing but concurrency (bits identical to an open engine
    /// of the same geometry) and the capped split dot is counted.
    #[test]
    fn governed_split_is_bit_identical_and_counted() {
        let mut c = cfg(2, 64 << 10, 4);
        c.engine.governance = false;
        let mut governed = ShardedEngine::from_topology(&Topology::fake_even(2), c);
        governed.set_worker_caps([[1, 1, 1], [1, 1, 1]]);
        let open = ShardedEngine::from_topology(&Topology::fake_even(2), c);
        let mut rng = Rng::new(59);
        let n = 100_000; // 800 KB total >> 64 KB split threshold
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let x = governed.dot_f32(Accuracy::Kahan, &a, &b);
        let y = open.dot_f32(Accuracy::Kahan, &a, &b);
        assert_eq!(x.to_bits(), y.to_bits(), "a worker cap must never change bits");
        let (gs, os) = (governed.stats(), open.stats());
        assert_eq!(gs.split_dots, 1, "{gs:?}");
        assert_eq!(gs.capped_requests, 1, "{gs:?}");
        assert_eq!(os.capped_requests, 0, "{os:?}");
    }

    /// Quarantine at the split layer: weighting a shard out of the
    /// chunk-block assignment moves its chunks onto healthy shards but
    /// never changes the chunk geometry or merge order — bits identical
    /// to the all-healthy split. Fresh routing skips the quarantined
    /// shard; reinstatement restores both.
    #[test]
    fn quarantined_split_is_bit_identical_and_rerouted() {
        let sharded = ShardedEngine::from_topology(&Topology::fake_even(2), cfg(1, 64 << 10, 4));
        let mut rng = Rng::new(61);
        let n = 100_000; // 800 KB total >> 64 KB split threshold
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let healthy = sharded.dot_f32(Accuracy::Kahan, &a, &b);
        sharded.quarantine(1);
        assert!(sharded.is_quarantined(1));
        let degraded = sharded.dot_f32(Accuracy::Kahan, &a, &b);
        assert_eq!(
            healthy.to_bits(),
            degraded.to_bits(),
            "quarantine must never change bits"
        );
        // shard 1 served none of the degraded split's chunks
        let before = sharded.shard(1).stats();
        // fresh (sub-split) routing skips the quarantined shard
        let small = rng.normal_f32_vec(1000);
        for _ in 0..4 {
            sharded.dot_f32(Accuracy::Kahan, &small, &small);
        }
        let after = sharded.shard(1).stats();
        assert_eq!(
            after.requests, before.requests,
            "fresh routing must skip a quarantined shard"
        );
        sharded.reinstate(1);
        assert!(!sharded.is_quarantined(1));
        let restored = sharded.dot_f32(Accuracy::Kahan, &a, &b);
        assert_eq!(healthy.to_bits(), restored.to_bits());
        // all-quarantined: the mask is ignored and serving continues
        sharded.quarantine(0);
        sharded.quarantine(1);
        let last_resort = sharded.dot_f32(Accuracy::Kahan, &a, &b);
        assert_eq!(healthy.to_bits(), last_resort.to_bits());
    }

    /// `split_min_bytes == 0` resolves at construction (profile-derived
    /// crossover, else the documented default) and records where the
    /// number came from; explicit values pass through untouched.
    #[test]
    fn auto_split_threshold_resolves_and_records_provenance() {
        let auto = ShardedEngine::from_topology(&Topology::single_node(), cfg(1, 0, 0));
        assert_ne!(auto.config().split_min_bytes, 0, "auto must resolve to a real threshold");
        assert_ne!(auto.split_min_source(), "explicit (config)");
        let explicit = ShardedEngine::from_topology(&Topology::single_node(), cfg(1, 123 << 10, 0));
        assert_eq!(explicit.config().split_min_bytes, 123 << 10);
        assert_eq!(explicit.split_min_source(), "explicit (config)");
    }

    /// Deadline promotion at the sharded layer: a synthetic calibration
    /// that projects the one-shard parallel path over the deadline and the
    /// split path under it makes `dot_on_deadline_*` serve via Split —
    /// bit-identical to the un-promoted route, counted in
    /// `deadline_splits`. No deadline, or a deadline everything fits,
    /// promotes nothing.
    #[test]
    fn deadline_promotion_splits_bit_identically_and_is_counted() {
        use crate::engine::plan::PlanCalibration;
        // chunks (2) == per-shard workers (2): the promotion's
        // chunk-geometry gate holds, so Split reproduces the parallel
        // path's exact partials and fold
        let mut sharded =
            ShardedEngine::from_topology(&Topology::fake_even(2), cfg(2, 64 << 20, 2));
        sharded.set_calibration(PlanCalibration {
            shard_gbs: [[1.0; 3]; 2],  // slow single shard: ~1 GB/s
            split_gbs: [[10.0; 3]; 2], // split across both: 10 GB/s
            split_fixed_us: 0.0,
            kahan_vs_naive: [0.5, 0.9, 0.99],
            dot2_vs_naive: [0.4, 0.8, 0.97],
        });
        let mut rng = Rng::new(67);
        // 2.4 MB total: Parallel route, far below the 64 MB split floor
        let n = 300_000;
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let base = sharded.dot_on_f32(0, Accuracy::Kahan, &a, &b);
        // projected parallel ≈ 2400 µs blows the 1000 µs deadline;
        // projected split ≈ 240 µs fits → promoted
        let promoted = sharded.dot_on_deadline_f32(0, Accuracy::Kahan, 1000, &a, &b);
        assert_eq!(base.to_bits(), promoted.to_bits(), "promotion must never change bits");
        let st = sharded.stats();
        assert_eq!(st.deadline_splits, 1, "{st:?}");
        assert_eq!(st.split_dots, 1, "{st:?}");
        // no deadline, and a deadline the parallel path fits: no promotion
        let none = sharded.dot_on_deadline_f32(0, Accuracy::Kahan, 0, &a, &b);
        assert_eq!(base.to_bits(), none.to_bits());
        let relaxed = sharded.dot_on_deadline_f32(0, Accuracy::Kahan, 1_000_000, &a, &b);
        assert_eq!(base.to_bits(), relaxed.to_bits());
        assert_eq!(sharded.stats().deadline_splits, 1);
    }

    #[test]
    fn global_sharded_engine_is_a_singleton() {
        let a = ShardedEngine::global() as *const _;
        let b = ShardedEngine::global() as *const _;
        assert_eq!(a, b);
    }
}
