//! First-use micro-calibration: which registry kernel is fastest on *this*
//! host at each size class?
//!
//! The paper's answer is analytic (ECM: the best kernel depends on which
//! level of the memory hierarchy bounds the loop), but silicon has the last
//! word — AVX-512 downclocking, missing FMA, SMT siblings and virtualized
//! LLCs all shuffle the ranking. So on first use the engine times every
//! available kernel from `bench::kernels` at three probe sizes
//! (L1-resident, LLC-resident, memory-resident), picks the fastest naive
//! and fastest compensated kernel per `(Precision, SizeClass)`, and caches
//! the dispatch table in a `OnceLock` for the life of the process.
//!
//! Calibration costs ~1 s once; every later `select` is an array index.

use crate::bench::kernels::{registry_static, HostKernel, KernelFn};
use crate::bench::timer::measure_adaptive;
use crate::isa::{Precision, Variant};
use crate::machine::detect::detect_host_cached;
use crate::util::Rng;
use std::sync::OnceLock;

/// Where a working set of a given total size lives on this host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeClass {
    /// both streams fit in L1
    L1,
    /// fits in the last-level cache
    Llc,
    /// memory-resident
    Mem,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::L1, SizeClass::Llc, SizeClass::Mem];

    pub fn index(self) -> usize {
        match self {
            SizeClass::L1 => 0,
            SizeClass::Llc => 1,
            SizeClass::Mem => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::L1 => "L1",
            SizeClass::Llc => "LLC",
            SizeClass::Mem => "MEM",
        }
    }

    /// Classify a total working-set size (both streams, bytes) against the
    /// detected host cache hierarchy.
    pub fn of(total_bytes: u64) -> SizeClass {
        let m = detect_host_cached();
        if total_bytes <= m.caches[0].size_bytes {
            SizeClass::L1
        } else if total_bytes <= m.caches[2].size_bytes {
            SizeClass::Llc
        } else {
            SizeClass::Mem
        }
    }
}

fn prec_index(prec: Precision) -> usize {
    match prec {
        Precision::Sp => 0,
        Precision::Dp => 1,
    }
}

/// The two kernels the engine dispatches between for one
/// `(Precision, SizeClass)` cell.
#[derive(Clone, Copy)]
pub struct Choice {
    /// fastest compensated kernel (Kahan or Kahan-FMA)
    pub kahan: HostKernel,
    /// fastest uncompensated kernel
    pub naive: HostKernel,
    /// measured cycles per invocation at the probe size, (kahan, naive)
    pub probe_cy: (f64, f64),
}

/// Calibrated dispatch table: `[precision][size class] -> Choice`.
pub struct DispatchTable {
    choices: [[Choice; 3]; 2],
    /// total probe bytes used per class (for reporting)
    pub probe_bytes: [u64; 3],
}

fn median_cycles_f32(f: fn(&[f32], &[f32]) -> f32, a: &[f32], b: &[f32], reps: usize) -> f64 {
    measure_adaptive(200_000.0, reps, || f(a, b)).median_cy
}

fn median_cycles_f64(f: fn(&[f64], &[f64]) -> f64, a: &[f64], b: &[f64], reps: usize) -> f64 {
    measure_adaptive(200_000.0, reps, || f(a, b)).median_cy
}

impl DispatchTable {
    /// Time every available kernel at each probe size and keep the winners.
    /// `probe_bytes[c]` is the total working set (both streams) for class
    /// `c`; tests pass tiny probes to keep calibration instant.
    pub fn calibrate(probe_bytes: [u64; 3], reps: usize) -> DispatchTable {
        let mut rng = Rng::new(0xCA11B);
        let mut rows: Vec<[Choice; 3]> = Vec::with_capacity(2);
        for prec in [Precision::Sp, Precision::Dp] {
            let elem = match prec {
                Precision::Sp => 4u64,
                Precision::Dp => 8u64,
            };
            let mut per_class: Vec<Choice> = Vec::with_capacity(3);
            for &total in &probe_bytes {
                let n = (total / (2 * elem)).max(64) as usize;
                let mut best_kahan: Option<(f64, HostKernel)> = None;
                let mut best_naive: Option<(f64, HostKernel)> = None;
                match prec {
                    Precision::Sp => {
                        let a = rng.normal_f32_vec(n);
                        let b = rng.normal_f32_vec(n);
                        for k in registry_static().iter().filter(|k| k.available) {
                            let KernelFn::F32(f) = k.f else { continue };
                            if k.prec != prec {
                                continue;
                            }
                            let cy = median_cycles_f32(f, &a, &b, reps);
                            let slot = if k.variant == Variant::Naive {
                                &mut best_naive
                            } else {
                                &mut best_kahan
                            };
                            if slot.map_or(true, |(c, _)| cy < c) {
                                *slot = Some((cy, *k));
                            }
                        }
                    }
                    Precision::Dp => {
                        let a = rng.normal_f64_vec(n);
                        let b = rng.normal_f64_vec(n);
                        for k in registry_static().iter().filter(|k| k.available) {
                            let KernelFn::F64(f) = k.f else { continue };
                            if k.prec != prec {
                                continue;
                            }
                            let cy = median_cycles_f64(f, &a, &b, reps);
                            let slot = if k.variant == Variant::Naive {
                                &mut best_naive
                            } else {
                                &mut best_kahan
                            };
                            if slot.map_or(true, |(c, _)| cy < c) {
                                *slot = Some((cy, *k));
                            }
                        }
                    }
                }
                // scalar naive + scalar kahan are always available, so both
                // slots are guaranteed to be filled
                let (kc, kahan) = best_kahan.expect("at least one compensated kernel");
                let (nc, naive) = best_naive.expect("at least one naive kernel");
                per_class.push(Choice { kahan, naive, probe_cy: (kc, nc) });
            }
            rows.push([per_class[0], per_class[1], per_class[2]]);
        }
        DispatchTable { choices: [rows[0], rows[1]], probe_bytes }
    }

    pub fn choice(&self, prec: Precision, class: SizeClass) -> &Choice {
        &self.choices[prec_index(prec)][class.index()]
    }

    /// Kernel for a request: `Variant::Naive` maps to the naive winner,
    /// every compensated variant maps to the Kahan winner.
    pub fn select(&self, prec: Precision, variant: Variant, class: SizeClass) -> &HostKernel {
        let c = self.choice(prec, class);
        if variant == Variant::Naive {
            &c.naive
        } else {
            &c.kahan
        }
    }

    /// Human-readable dispatch table (for `repro engine-info` and benches).
    pub fn render(&self) -> crate::util::Table {
        let mut t = crate::util::Table::new("autotuned kernel dispatch (per size class)")
            .headers(["prec", "class", "probe WS", "kahan winner", "naive winner"]);
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                let c = self.choice(prec, class);
                t.row([
                    if prec == Precision::Sp { "SP" } else { "DP" }.to_string(),
                    class.name().to_string(),
                    crate::util::fmt::bytes(self.probe_bytes[class.index()]),
                    format!("{} ({:.0} cy)", c.kahan.name, c.probe_cy.0),
                    format!("{} ({:.0} cy)", c.naive.name, c.probe_cy.1),
                ]);
            }
        }
        t
    }
}

/// Default probe sizes from the detected cache hierarchy: half-L1,
/// half-LLC, and a memory-resident set strictly beyond the LLC (capped at
/// 64 MiB so first-use calibration stays around a second).
fn default_probe_bytes() -> [u64; 3] {
    let m = detect_host_cached();
    let l1 = m.caches[0].size_bytes / 2;
    let llc_full = m.caches[2].size_bytes;
    let mem = (2 * llc_full).min(64 << 20).max(llc_full + (8 << 20));
    [l1, llc_full / 2, mem]
}

/// The process-wide dispatch table, calibrated on first use.
pub fn dispatch() -> &'static DispatchTable {
    static TABLE: OnceLock<DispatchTable> = OnceLock::new();
    TABLE.get_or_init(|| DispatchTable::calibrate(default_probe_bytes(), 3))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny probes keep this test fast; we only assert structure, not that
    /// any particular kernel wins.
    #[test]
    fn calibrate_fills_every_cell_with_matching_kernels() {
        let t = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                let c = t.choice(prec, class);
                assert_eq!(c.kahan.prec, prec);
                assert_eq!(c.naive.prec, prec);
                assert!(c.kahan.available && c.naive.available);
                assert_ne!(c.kahan.variant, Variant::Naive);
                assert_eq!(c.naive.variant, Variant::Naive);
                assert!(c.probe_cy.0 > 0.0 && c.probe_cy.1 > 0.0);
            }
        }
        // select maps variants onto the right column
        let k = t.select(Precision::Sp, Variant::Kahan, SizeClass::L1);
        assert_ne!(k.variant, Variant::Naive);
        let n = t.select(Precision::Sp, Variant::Naive, SizeClass::Mem);
        assert_eq!(n.variant, Variant::Naive);
        // render shouldn't panic
        let _ = t.render().render();
    }

    #[test]
    fn size_class_ordering_is_monotone() {
        let m = detect_host_cached();
        assert_eq!(SizeClass::of(1024), SizeClass::L1);
        assert_eq!(SizeClass::of(m.caches[2].size_bytes), SizeClass::Llc);
        assert_eq!(SizeClass::of(4 * m.caches[2].size_bytes), SizeClass::Mem);
    }
}
