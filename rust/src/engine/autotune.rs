//! First-use micro-calibration: which registry kernel is fastest on *this*
//! host at each size class?
//!
//! The paper's answer is analytic (ECM: the best kernel depends on which
//! level of the memory hierarchy bounds the loop), but silicon has the last
//! word — AVX-512 downclocking, missing FMA, SMT siblings and virtualized
//! LLCs all shuffle the ranking. So on first use the engine times every
//! available kernel from `bench::kernels` at three probe sizes
//! (L1-resident, LLC-resident, memory-resident), picks the fastest naive
//! and fastest compensated kernel per `(Precision, SizeClass)`, and caches
//! the dispatch table in a `OnceLock` for the life of the process.
//!
//! Probe buffers come from a recycling [`BufferPool`], so calibration
//! measures the same 64-byte-aligned memory the serving path streams
//! (the kernels' aligned-load fast path included), not cold fresh `Vec`s.
//!
//! The table also carries a **batched-kernel choice** per cell: if the
//! cell's single winner has a fused multi-dot twin
//! (`bench::kernels::batch`), the twin is timed against a serial loop of
//! the winner at the probe size, and kept only where fusion wins. The kept
//! set is forced monotone over size classes — batching never applies above
//! the class where it stops winning — and the memory-resident class is
//! always serial (a memory-bound dot gains nothing from fusing and the
//! engine's small-dot batching never reaches that size anyway).
//!
//! Calibration costs ~1 s once; every later `select` is an array index.

use super::pool::BufferPool;
use super::profile::CalibrationProfile;
use crate::bench::kernels::batch::{batch_for, BatchKernel, BatchKernelFn};
use crate::bench::kernels::{by_name, registry_static, HostKernel, KernelFn};
use crate::bench::timer::measure_adaptive;
use crate::isa::{Accuracy, Precision};
use crate::machine::detect::detect_host_cached;
use crate::util::Rng;
use std::sync::OnceLock;

/// Where a working set of a given total size lives on this host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeClass {
    /// both streams fit in L1
    L1,
    /// fits in the last-level cache
    Llc,
    /// memory-resident
    Mem,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::L1, SizeClass::Llc, SizeClass::Mem];

    pub fn index(self) -> usize {
        match self {
            SizeClass::L1 => 0,
            SizeClass::Llc => 1,
            SizeClass::Mem => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::L1 => "L1",
            SizeClass::Llc => "LLC",
            SizeClass::Mem => "MEM",
        }
    }

    /// Classify a total working-set size (both streams, bytes) against the
    /// detected host cache hierarchy.
    pub fn of(total_bytes: u64) -> SizeClass {
        let m = detect_host_cached();
        if total_bytes <= m.caches[0].size_bytes {
            SizeClass::L1
        } else if total_bytes <= m.caches[2].size_bytes {
            SizeClass::Llc
        } else {
            SizeClass::Mem
        }
    }
}

/// Row index of a precision in the `[precision][size class]` tables shared
/// by the dispatch table, `PlanPolicy::worker_caps`, and the ECM verdict.
pub(crate) fn prec_index(prec: Precision) -> usize {
    match prec {
        Precision::Sp => 0,
        Precision::Dp => 1,
    }
}

/// Column index of an accuracy tier in the per-cell winner tables.
pub(crate) fn acc_index(acc: Accuracy) -> usize {
    match acc {
        Accuracy::Naive => 0,
        Accuracy::Kahan => 1,
        Accuracy::Dot2 => 2,
        Accuracy::Exact => 3,
    }
}

/// Requests fused per batch probe (and the divisor for per-request cycles).
const BATCH_PROBE_B: usize = 4;

/// Per-request working-set cap for batch probes: batching is a small-dot
/// mechanism, so the LLC-class probe is measured at a serving-realistic
/// request size instead of B half-LLC monsters.
const BATCH_PROBE_MAX_BYTES: u64 = 512 << 10;

/// The batched-execution decision for one `(Precision, Accuracy, SizeClass)`
/// cell: the fused twin of the cell's single winner, kept only where
/// calibration showed fusion winning (else the engine loops the single
/// kernel — batching above the handoff layer still applies).
#[derive(Clone, Copy)]
pub struct BatchChoice {
    /// fused multi-dot kernel bit-identical (per request) to the cell's
    /// single winner; `None` = serial execution within a batch
    pub fused: Option<&'static BatchKernel>,
    /// measured per-request cycles at the probe, (fused, serial);
    /// `(0.0, 0.0)` when the cell was not probed (no twin, or MEM class)
    pub probe_cy: (f64, f64),
}

impl BatchChoice {
    fn unmeasured() -> BatchChoice {
        BatchChoice { fused: None, probe_cy: (0.0, 0.0) }
    }
}

/// The kernels the engine dispatches between for one
/// `(Precision, SizeClass)` cell: one winner (plus fused-batch decision)
/// per accuracy tier, indexed by [`acc_index`].
#[derive(Clone, Copy)]
pub struct Choice {
    winners: [HostKernel; 4],
    probe: [f64; 4],
    batches: [BatchChoice; 4],
}

impl Choice {
    /// The tier's fastest available kernel in this cell. The `Exact` tier
    /// is never timed (its expansion path at MEM probe size would dominate
    /// calibration); it has exactly one registry kernel per precision.
    pub fn winner(&self, acc: Accuracy) -> &HostKernel {
        &self.winners[acc_index(acc)]
    }

    /// Measured cycles per invocation at the probe size (0.0 for `Exact`).
    pub fn probe_cy(&self, acc: Accuracy) -> f64 {
        self.probe[acc_index(acc)]
    }

    /// The tier's fused-batch decision in this cell.
    pub fn batch(&self, acc: Accuracy) -> &BatchChoice {
        &self.batches[acc_index(acc)]
    }
}

/// Calibrated dispatch table: `[precision][size class] -> Choice`.
pub struct DispatchTable {
    choices: [[Choice; 3]; 2],
    /// total probe bytes used per class (for reporting)
    pub probe_bytes: [u64; 3],
    /// ECM governance correction per (precision, size class), fixed-point
    /// millis (1000 = 1.0): observed/predicted saturation from the bench's
    /// empirical sweep, applied by [`DispatchTable::corrected_sat`] when a
    /// misprediction exceeded tolerance. Each cell learns independently —
    /// the saturation point genuinely differs between an L1-resident and a
    /// memory-resident loop, so one blended factor would mis-correct both.
    /// Lives here — not in `PlanPolicy` — because it is *measured
    /// calibration state* like the kernel choices, while the policy stays
    /// a pure function of its config.
    sat_scale: [[std::sync::atomic::AtomicU32; 3]; 2],
}

fn median_cycles_f32(f: fn(&[f32], &[f32]) -> f32, a: &[f32], b: &[f32], reps: usize) -> f64 {
    measure_adaptive(200_000.0, reps, || f(a, b)).median_cy
}

fn median_cycles_f64(f: fn(&[f64], &[f64]) -> f64, a: &[f64], b: &[f64], reps: usize) -> f64 {
    measure_adaptive(200_000.0, reps, || f(a, b)).median_cy
}

/// Generates the per-precision batch-probe helper: time the fused twin of
/// `winner` against a serial loop of `winner` over [`BATCH_PROBE_B`]
/// distinct pooled pairs, and keep the twin only if it wins.
macro_rules! probe_batch_impl {
    ($name:ident, $ty:ty, $gen:ident, $kernel_variant:ident, $batch_variant:ident) => {
        fn $name(
            pool: &std::sync::Arc<BufferPool>,
            rng: &mut Rng,
            total_bytes: u64,
            reps: usize,
            winner: &HostKernel,
        ) -> BatchChoice {
            let Some(bk) = batch_for(winner.name) else {
                return BatchChoice::unmeasured();
            };
            let (KernelFn::$kernel_variant(f), BatchKernelFn::$batch_variant(bf)) =
                (winner.f, bk.f)
            else {
                return BatchChoice::unmeasured();
            };
            let per_req = total_bytes.min(BATCH_PROBE_MAX_BYTES);
            let n = (per_req / (2 * std::mem::size_of::<$ty>() as u64)).max(64) as usize;
            let data: Vec<_> = (0..BATCH_PROBE_B)
                .map(|_| {
                    let av = rng.$gen(n);
                    let bv = rng.$gen(n);
                    (pool.admit(&av), pool.admit(&bv))
                })
                .collect();
            let pairs: Vec<(&[$ty], &[$ty])> =
                data.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
            let mut vals = vec![0.0 as $ty; BATCH_PROBE_B];
            let fused_cy = measure_adaptive(200_000.0, reps, || {
                bf(&pairs, &mut vals);
                vals[0]
            })
            .median_cy
                / BATCH_PROBE_B as f64;
            let serial_cy = measure_adaptive(200_000.0, reps, || {
                let mut acc = 0.0 as $ty;
                for &(a, b) in &pairs {
                    acc += std::hint::black_box(f(a, b));
                }
                acc
            })
            .median_cy
                / BATCH_PROBE_B as f64;
            BatchChoice { fused: (fused_cy < serial_cy).then_some(bk), probe_cy: (fused_cy, serial_cy) }
        }
    };
}

probe_batch_impl!(probe_batch_f32, f32, normal_f32_vec, F32, F32);
probe_batch_impl!(probe_batch_f64, f64, normal_f64_vec, F64, F64);

impl DispatchTable {
    /// Time every available kernel at each probe size and keep the winners.
    /// `probe_bytes[c]` is the total working set (both streams) for class
    /// `c`; tests pass tiny probes to keep calibration instant.
    pub fn calibrate(probe_bytes: [u64; 3], reps: usize) -> DispatchTable {
        let mut rng = Rng::new(0xCA11B);
        // probe inputs live in a recycling pool: calibration streams the
        // same 64-byte-aligned recycled memory the serving path uses
        let pool = BufferPool::new();
        let mut rows: Vec<[Choice; 3]> = Vec::with_capacity(2);
        for prec in [Precision::Sp, Precision::Dp] {
            let elem = match prec {
                Precision::Sp => 4u64,
                Precision::Dp => 8u64,
            };
            let mut per_class: Vec<Choice> = Vec::with_capacity(3);
            // tiers whose winners are timed and batch-probed; Exact is
            // selected without timing (sole entry per precision, and its
            // expansion path at the MEM probe would dominate calibration)
            const TIMED: [Accuracy; 3] = [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2];
            for (ci, &total) in probe_bytes.iter().enumerate() {
                let n = (total / (2 * elem)).max(64) as usize;
                let mut best: [Option<(f64, HostKernel)>; 4] = [None; 4];
                let mut batches = [BatchChoice::unmeasured(); 4];
                match prec {
                    Precision::Sp => {
                        let av = rng.normal_f32_vec(n);
                        let bv = rng.normal_f32_vec(n);
                        let a = pool.admit(&av);
                        let b = pool.admit(&bv);
                        for k in registry_static().iter().filter(|k| k.available) {
                            let KernelFn::F32(f) = k.f else { continue };
                            if k.prec != prec {
                                continue;
                            }
                            let slot = &mut best[acc_index(k.accuracy)];
                            if k.accuracy == Accuracy::Exact {
                                if slot.is_none() {
                                    *slot = Some((0.0, *k));
                                }
                                continue;
                            }
                            let cy = median_cycles_f32(f, a.as_slice(), b.as_slice(), reps);
                            if slot.map_or(true, |(c, _)| cy < c) {
                                *slot = Some((cy, *k));
                            }
                        }
                        if ci < SizeClass::Mem.index() {
                            for acc in TIMED {
                                let (_, w) = best[acc_index(acc)].expect("tier winner");
                                batches[acc_index(acc)] =
                                    probe_batch_f32(&pool, &mut rng, total, reps, &w);
                            }
                        }
                    }
                    Precision::Dp => {
                        let av = rng.normal_f64_vec(n);
                        let bv = rng.normal_f64_vec(n);
                        let a = pool.admit(&av);
                        let b = pool.admit(&bv);
                        for k in registry_static().iter().filter(|k| k.available) {
                            let KernelFn::F64(f) = k.f else { continue };
                            if k.prec != prec {
                                continue;
                            }
                            let slot = &mut best[acc_index(k.accuracy)];
                            if k.accuracy == Accuracy::Exact {
                                if slot.is_none() {
                                    *slot = Some((0.0, *k));
                                }
                                continue;
                            }
                            let cy = median_cycles_f64(f, a.as_slice(), b.as_slice(), reps);
                            if slot.map_or(true, |(c, _)| cy < c) {
                                *slot = Some((cy, *k));
                            }
                        }
                        if ci < SizeClass::Mem.index() {
                            for acc in TIMED {
                                let (_, w) = best[acc_index(acc)].expect("tier winner");
                                batches[acc_index(acc)] =
                                    probe_batch_f64(&pool, &mut rng, total, reps, &w);
                            }
                        }
                    }
                }
                // every tier has an always-available scalar kernel, so every
                // slot is guaranteed to be filled
                let filled =
                    best.map(|o| o.expect("every accuracy tier has an always-available kernel"));
                per_class.push(Choice {
                    winners: filled.map(|(_, k)| k),
                    probe: filled.map(|(c, _)| c),
                    batches,
                });
            }
            // the calibrated batch cutoff: batching must never be used
            // above the size class where it stops winning, so once a class
            // comes out serial every larger class is forced serial too
            let mut on = [true; 4];
            for c in per_class.iter_mut() {
                for (t, keep) in on.iter_mut().enumerate() {
                    if !*keep {
                        c.batches[t].fused = None;
                    }
                    *keep &= c.batches[t].fused.is_some();
                }
            }
            rows.push([per_class[0], per_class[1], per_class[2]]);
        }
        DispatchTable {
            choices: [rows[0], rows[1]],
            probe_bytes,
            sat_scale: std::array::from_fn(|_| {
                std::array::from_fn(|_| std::sync::atomic::AtomicU32::new(1000))
            }),
        }
    }

    /// Feed back one predicted-vs-observed saturation measurement (from
    /// `bench_engine`'s empirical sweep). When the relative misprediction
    /// exceeds `tol`, the stored correction becomes observed/predicted
    /// (clamped to [0.25, 4.0] so one noisy sweep cannot collapse or
    /// explode the cap); within tolerance the correction resets to 1.0.
    pub fn note_saturation(
        &self,
        prec: Precision,
        class: SizeClass,
        predicted: u32,
        observed: u32,
        tol: f64,
    ) {
        use std::sync::atomic::Ordering;
        if predicted == 0 || observed == 0 {
            return;
        }
        let rel = (observed as f64 - predicted as f64).abs() / predicted as f64;
        let scale = if rel > tol {
            (observed as f64 / predicted as f64).clamp(0.25, 4.0)
        } else {
            1.0
        };
        self.sat_scale[prec_index(prec)][class.index()]
            .store((scale * 1000.0).round() as u32, Ordering::Relaxed);
    }

    /// Apply the stored saturation correction for one `(precision, size
    /// class)` cell to a model-predicted cap. `usize::MAX` means "uncapped"
    /// and passes through untouched; a corrected cap never drops below one
    /// worker.
    pub fn corrected_sat(&self, prec: Precision, class: SizeClass, base: usize) -> usize {
        use std::sync::atomic::Ordering;
        if base == usize::MAX {
            return usize::MAX;
        }
        let scale =
            self.sat_scale[prec_index(prec)][class.index()].load(Ordering::Relaxed) as f64 / 1000.0;
        ((base as f64 * scale).round() as usize).max(1)
    }

    /// The stored saturation correction for one cell as a plain factor
    /// (1.0 = identity). Read by [`CalibrationProfile::measure`] so a
    /// `repro calibrate --write` run persists what the bench sweep taught
    /// this process.
    pub fn sat_scale(&self, prec: Precision, class: SizeClass) -> f64 {
        use std::sync::atomic::Ordering;
        self.sat_scale[prec_index(prec)][class.index()].load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Seed the saturation correction for one cell from a persisted
    /// profile (same clamp as [`DispatchTable::note_saturation`], so a
    /// corrupt-but-parsable factor cannot collapse or explode the cap).
    pub fn set_sat_scale(&self, prec: Precision, class: SizeClass, scale: f64) {
        use std::sync::atomic::Ordering;
        let clamped = if scale.is_finite() { scale.clamp(0.25, 4.0) } else { 1.0 };
        self.sat_scale[prec_index(prec)][class.index()]
            .store((clamped * 1000.0).round() as u32, Ordering::Relaxed);
    }

    /// Rebuild a dispatch table from a persisted [`CalibrationProfile`]
    /// instead of re-probing every kernel: winners and fused-batch choices
    /// resolve by name against the live registry (so a profile can never
    /// smuggle in a kernel this build does not have), probe cycles carry
    /// over for reporting and ratio math, and the saturation corrections
    /// seed from what the profiled run learned. Any mismatch — unknown or
    /// unavailable kernel, tier/precision confusion, a fused choice that is
    /// not the winner's twin, or a batch on the memory class — rejects the
    /// whole profile; the caller falls back to live calibration.
    pub fn from_profile(p: &CalibrationProfile) -> Result<DispatchTable, String> {
        let mut rows: Vec<[Choice; 3]> = Vec::with_capacity(2);
        for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
            let mut per_class: Vec<Choice> = Vec::with_capacity(3);
            for ci in 0..3 {
                let mut winners: [Option<HostKernel>; 4] = [None; 4];
                let mut probe = [0.0f64; 4];
                let mut batches = [BatchChoice::unmeasured(); 4];
                for acc in Accuracy::ALL {
                    let ti = acc_index(acc);
                    let name = p.winners[pi][ci][ti].as_str();
                    let k = by_name(name)
                        .ok_or_else(|| format!("profile winner '{name}' is not in the registry"))?;
                    if !k.available || k.prec != prec || k.accuracy != acc {
                        return Err(format!(
                            "profile winner '{name}' does not fit cell ({} {} {})",
                            prec.name(),
                            SizeClass::ALL[ci].name(),
                            acc.name()
                        ));
                    }
                    winners[ti] = Some(k);
                    let cy = p.probe_cy[pi][ci][ti];
                    probe[ti] = if cy.is_finite() && cy >= 0.0 { cy } else { 0.0 };
                    let bname = p.batches[pi][ci][ti].as_str();
                    if !bname.is_empty() {
                        if ci >= SizeClass::Mem.index() {
                            return Err(format!(
                                "profile batches the memory class ('{bname}')"
                            ));
                        }
                        let bk = batch_for(k.name)
                            .filter(|bk| bk.name == bname && bk.available)
                            .ok_or_else(|| {
                                format!("profile batch '{bname}' is not the twin of '{name}'")
                            })?;
                        batches[ti] = BatchChoice { fused: Some(bk), probe_cy: (0.0, 0.0) };
                    }
                }
                per_class.push(Choice {
                    winners: winners.map(|o| o.expect("every tier resolved above")),
                    probe,
                    batches,
                });
            }
            // same monotone cutoff as live calibration: a profile edited to
            // batch LLC but not L1 degrades to the safe serial choice
            let mut on = [true; 4];
            for c in per_class.iter_mut() {
                for (t, keep) in on.iter_mut().enumerate() {
                    if !*keep {
                        c.batches[t].fused = None;
                    }
                    *keep &= c.batches[t].fused.is_some();
                }
            }
            rows.push([per_class[0], per_class[1], per_class[2]]);
        }
        let t = DispatchTable {
            choices: [rows[0], rows[1]],
            probe_bytes: default_probe_bytes(),
            sat_scale: std::array::from_fn(|_| {
                std::array::from_fn(|_| std::sync::atomic::AtomicU32::new(1000))
            }),
        };
        p.seed_saturation(&t);
        Ok(t)
    }

    pub fn choice(&self, prec: Precision, class: SizeClass) -> &Choice {
        &self.choices[prec_index(prec)][class.index()]
    }

    /// Kernel for a request: the requested accuracy tier's winner in this
    /// `(precision, size class)` cell.
    pub fn select(&self, prec: Precision, accuracy: Accuracy, class: SizeClass) -> &HostKernel {
        self.choice(prec, class).winner(accuracy)
    }

    /// Fused multi-dot kernel for a batch of requests in this cell, if
    /// calibration kept one. `None` means: execute the batch as a serial
    /// loop of the single winner (request coalescing above the kernel
    /// still applies; Dot2 and Exact have no fused twins and always come
    /// back serial). The returned kernel is bit-identical, per request,
    /// to what [`DispatchTable::select`] returns for the same cell.
    pub fn select_batch(
        &self,
        prec: Precision,
        accuracy: Accuracy,
        class: SizeClass,
    ) -> Option<&'static BatchKernel> {
        self.choice(prec, class).batch(accuracy).fused
    }

    /// Human-readable dispatch table (for `repro engine-info` and benches).
    pub fn render(&self) -> crate::util::Table {
        fn batched(b: &BatchChoice) -> String {
            match b.fused {
                Some(bk) => format!("{} ({:.0} vs {:.0} cy/req)", bk.name, b.probe_cy.0, b.probe_cy.1),
                None if b.probe_cy.1 > 0.0 => "serial (fusion lost probe)".to_string(),
                None => "serial".to_string(),
            }
        }
        fn winner(c: &Choice, acc: Accuracy) -> String {
            if acc == Accuracy::Exact {
                c.winner(acc).name.to_string()
            } else {
                format!("{} ({:.0} cy)", c.winner(acc).name, c.probe_cy(acc))
            }
        }
        let mut t = crate::util::Table::new("autotuned kernel dispatch (per size class)")
            .headers(["prec", "class", "probe WS", "naive", "kahan", "dot2", "exact", "batched (kahan)"]);
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                let c = self.choice(prec, class);
                t.row([
                    if prec == Precision::Sp { "SP" } else { "DP" }.to_string(),
                    class.name().to_string(),
                    crate::util::fmt::bytes(self.probe_bytes[class.index()]),
                    winner(c, Accuracy::Naive),
                    winner(c, Accuracy::Kahan),
                    winner(c, Accuracy::Dot2),
                    winner(c, Accuracy::Exact),
                    batched(c.batch(Accuracy::Kahan)),
                ]);
            }
        }
        t
    }
}

/// Default probe sizes from the detected cache hierarchy: half-L1,
/// half-LLC, and a memory-resident set strictly beyond the LLC (capped at
/// 64 MiB so first-use calibration stays around a second).
fn default_probe_bytes() -> [u64; 3] {
    let m = detect_host_cached();
    let l1 = m.caches[0].size_bytes / 2;
    let llc_full = m.caches[2].size_bytes;
    let mem = (2 * llc_full).min(64 << 20).max(llc_full + (8 << 20));
    [l1, llc_full / 2, mem]
}

/// The process-wide dispatch table: seeded from the persisted calibration
/// profile when one loaded ([`super::profile::host_profile`]), else
/// calibrated live on first use. A profile that fails to resolve against
/// this build's registry counts as rejected and falls back to live
/// calibration — a stale file can cost the seeding win, never correctness.
pub fn dispatch() -> &'static DispatchTable {
    static TABLE: OnceLock<DispatchTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        if let Some(p) = super::profile::host_profile() {
            match DispatchTable::from_profile(p) {
                Ok(t) => return t,
                Err(_) => super::profile::note_rejected(),
            }
        }
        DispatchTable::calibrate(default_probe_bytes(), 3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny probes keep this test fast; we only assert structure, not that
    /// any particular kernel wins.
    #[test]
    fn calibrate_fills_every_cell_with_matching_kernels() {
        let t = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                let c = t.choice(prec, class);
                for acc in Accuracy::ALL {
                    let w = c.winner(acc);
                    assert_eq!(w.prec, prec);
                    assert_eq!(w.accuracy, acc, "winner must belong to its tier");
                    assert!(w.available);
                    if acc == Accuracy::Exact {
                        // never timed; exactly one scalar expansion kernel
                        assert_eq!(c.probe_cy(acc), 0.0);
                        assert_eq!(w.simd, crate::isa::Simd::Scalar);
                    } else {
                        assert!(c.probe_cy(acc) > 0.0);
                    }
                }
            }
        }
        // select maps tiers onto the right column
        for acc in Accuracy::ALL {
            let k = t.select(Precision::Sp, acc, SizeClass::L1);
            assert_eq!(k.accuracy, acc);
        }
        // render shouldn't panic
        let _ = t.render().render();
    }

    #[test]
    fn size_class_ordering_is_monotone() {
        let m = detect_host_cached();
        assert_eq!(SizeClass::of(1024), SizeClass::L1);
        assert_eq!(SizeClass::of(m.caches[2].size_bytes), SizeClass::Llc);
        assert_eq!(SizeClass::of(4 * m.caches[2].size_bytes), SizeClass::Mem);
    }

    /// The saturation-correction loop: identity by default, observed/
    /// predicted once a misprediction exceeds tolerance, uncapped cells
    /// untouched, floor of one worker, and every `(precision, size class)`
    /// cell learns independently.
    #[test]
    fn saturation_correction_applies_and_resets() {
        let t = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
        // default: identity
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::Mem, 4), 4);
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::Mem, usize::MAX), usize::MAX);
        // within tolerance: stays identity
        t.note_saturation(Precision::Sp, SizeClass::Mem, 4, 4, 0.25);
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::Mem, 4), 4);
        // beyond tolerance: scaled by observed/predicted
        t.note_saturation(Precision::Sp, SizeClass::Mem, 4, 8, 0.25);
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::Mem, 4), 8);
        assert_eq!(
            t.corrected_sat(Precision::Sp, SizeClass::Mem, usize::MAX),
            usize::MAX,
            "uncapped survives"
        );
        // sibling cells are independent: same precision other class, and
        // same class other precision, both stay identity
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::L1, 4), 4);
        assert_eq!(t.corrected_sat(Precision::Dp, SizeClass::Mem, 4), 4);
        // collapse is floored at one worker
        t.note_saturation(Precision::Dp, SizeClass::Llc, 8, 1, 0.25);
        assert_eq!(t.corrected_sat(Precision::Dp, SizeClass::Llc, 2), 1);
        assert_eq!(t.corrected_sat(Precision::Dp, SizeClass::Mem, 2), 2);
        // back within tolerance: reset to identity
        t.note_saturation(Precision::Sp, SizeClass::Mem, 4, 4, 0.25);
        assert_eq!(t.corrected_sat(Precision::Sp, SizeClass::Mem, 4), 4);
    }

    /// Profile seeding round-trips the table: a profile written from a
    /// calibrated table rebuilds one with the same winners, batch choices,
    /// probe cycles, and saturation corrections — and tampered profiles
    /// (unknown winner, wrong tier, MEM-class batch) are rejected whole.
    #[test]
    fn from_profile_round_trips_and_rejects_tampering() {
        let live = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
        live.note_saturation(Precision::Sp, SizeClass::Mem, 4, 8, 0.25);

        let mut p = CalibrationProfile {
            version: 1,
            machine: detect_host_cached().name.to_string(),
            threads: 4,
            shards: 1,
            mem_bw_gbs: 40.0,
            split_fixed_us: 10.0,
            kernel_gbs: [[10.0; 3]; 2],
            sat_cores: [[0; 3]; 2],
            sat_scale: [[1.0; 3]; 2],
            kahan_vs_naive: [0.5, 0.9, 0.99],
            dot2_vs_naive: [0.4, 0.8, 0.97],
            winners: Default::default(),
            probe_cy: [[[0.0; 4]; 3]; 2],
            batches: Default::default(),
        };
        for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
            for (ci, class) in SizeClass::ALL.into_iter().enumerate() {
                let c = live.choice(prec, class);
                p.sat_scale[pi][ci] = live.sat_scale(prec, class);
                for acc in Accuracy::ALL {
                    let ti = acc_index(acc);
                    p.winners[pi][ci][ti] = c.winner(acc).name.to_string();
                    p.probe_cy[pi][ci][ti] = c.probe_cy(acc);
                    p.batches[pi][ci][ti] =
                        c.batch(acc).fused.map(|b| b.name.to_string()).unwrap_or_default();
                }
            }
        }

        let seeded = DispatchTable::from_profile(&p).expect("faithful profile must seed");
        for prec in [Precision::Sp, Precision::Dp] {
            for class in SizeClass::ALL {
                for acc in Accuracy::ALL {
                    assert_eq!(
                        seeded.select(prec, acc, class).name,
                        live.select(prec, acc, class).name
                    );
                    assert_eq!(
                        seeded.select_batch(prec, acc, class).map(|b| b.name),
                        live.select_batch(prec, acc, class).map(|b| b.name)
                    );
                    assert_eq!(
                        seeded.choice(prec, class).probe_cy(acc),
                        live.choice(prec, class).probe_cy(acc)
                    );
                }
                assert_eq!(seeded.sat_scale(prec, class), live.sat_scale(prec, class));
            }
        }
        assert_eq!(seeded.corrected_sat(Precision::Sp, SizeClass::Mem, 4), 8);

        // tampering rejects the whole profile, never panics
        let mut bad = p.clone();
        bad.winners[0][0][0] = "no_such_kernel".to_string();
        assert!(DispatchTable::from_profile(&bad).is_err());
        let mut bad = p.clone();
        bad.winners[0][0][0] = p.winners[0][0][1].clone(); // kahan in naive slot
        assert!(DispatchTable::from_profile(&bad).is_err());
        let mut bad = p.clone();
        bad.batches[0][2][1] = "dot_f32_batch".to_string(); // MEM class batch
        assert!(DispatchTable::from_profile(&bad).is_err());
    }

    /// Batched-choice invariants: a kept fused kernel is always the twin of
    /// the cell's single winner, MEM is always serial, the kept set is
    /// monotone (no class may batch if a smaller one does not), and the
    /// tiers without fused twins (Dot2, Exact) always come back serial.
    #[test]
    fn batch_choice_pairs_with_winner_and_cutoff_is_monotone() {
        let t = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
        for prec in [Precision::Sp, Precision::Dp] {
            for acc in Accuracy::ALL {
                assert!(
                    t.select_batch(prec, acc, SizeClass::Mem).is_none(),
                    "memory-resident dots must never take the fused path"
                );
                let mut prev_on = true;
                for class in SizeClass::ALL {
                    let fused = t.select_batch(prec, acc, class);
                    if let Some(bk) = fused {
                        assert!(
                            prev_on,
                            "batch cutoff must be monotone over size classes"
                        );
                        let winner = t.select(prec, acc, class);
                        assert_eq!(
                            bk.matches, winner.name,
                            "fused kernel must be the twin of the single winner"
                        );
                        assert!(bk.available);
                    }
                    prev_on = fused.is_some();
                }
            }
            for acc in [Accuracy::Dot2, Accuracy::Exact] {
                for class in SizeClass::ALL {
                    assert!(
                        t.select_batch(prec, acc, class).is_none(),
                        "{} has no fused twin and must serial-loop",
                        acc.name()
                    );
                }
            }
        }
    }
}
