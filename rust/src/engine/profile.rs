//! Persistent measured-calibration profile: the numbers the planner
//! routes on, measured once and carried across process starts.
//!
//! The source paper's method is to replace guessed thresholds with
//! *measured* machine characteristics — and those characteristics shift
//! per host generation (Hofmann et al. 2016), so constants baked in for
//! one machine are wrong on the next. This module is where the measured
//! numbers live between runs:
//!
//! * [`CalibrationProfile::measure`] snapshots a one-shot calibration
//!   pass: per-(precision, size-class) kernel throughput from the
//!   autotuner's probe cycles, the ECM verdict's saturation cores plus
//!   the live observed-saturation corrections, the measured per-class
//!   accuracy-tier throughput ratios (`kahan_vs_naive`, `dot2_vs_naive`),
//!   the streaming load bandwidth, and the fixed fan-out/merge cost of a
//!   chunked parallel dot (`split_fixed_us`).
//! * The profile serializes to versioned flat-key JSON (hand-rolled like
//!   the BENCH artifacts — no serde dependency) at a configurable path:
//!   `REPRO_PROFILE` env var, `ServiceConfig::profile_path`, or the
//!   default `$TMPDIR/repro_calibration.json`. `repro calibrate --write`
//!   persists it; the engine loads it on first use.
//! * Consumers: `ShardedEngine::from_topology` derives `split_min_bytes`
//!   from the measured crossover ([`CalibrationProfile::derived_split_min_bytes`]),
//!   `PlanPolicy` takes a [`plan::PlanCalibration`] for deadline-aware
//!   routing projections and free accuracy upgrades,
//!   `DispatchTable::from_profile` seeds winners and saturation
//!   corrections so a cold process starts warmed up, and the service
//!   derives on-by-default wedge thresholds from the projected chunk
//!   service time.
//!
//! What a profile may change: thresholds (split crossover, wedge
//! timeouts), routing (deadline promotion), kernel *selection seeding*,
//! and concurrency caps. What it may never change: chunk geometry or the
//! bits of any served result — the same invariant as governance and
//! quarantine, property-tested in `rust/tests/test_profile.rs`.
//!
//! Rejection is always clean: a corrupt, stale (different machine), or
//! version-mismatched profile file is counted in the process-global
//! [`rejected_count`] (surfaced as `ServiceStats::profile_rejected`) and
//! every consumer falls back to the built-in defaults. Loading never
//! panics and never partially applies a profile.

use super::autotune::{acc_index, dispatch, prec_index, DispatchTable, SizeClass};
use super::plan::PlanCalibration;
use super::pool::BufferPool;
use super::topology::topology_cached;
use crate::bench::timer::measure_adaptive;
use crate::ecm::governance::{host_verdict, ModelSource};
use crate::isa::{Accuracy, Precision};
use crate::machine::detect::{calibrate_tsc_ghz_cached, detect_host_cached};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Format version — bump when the schema changes; older files are
/// rejected (counted, never partially parsed).
pub const PROFILE_VERSION: u64 = 1;

/// Magic `profile` field value identifying our files.
const PROFILE_MAGIC: &str = "repro_calibration";

/// Default file name under `std::env::temp_dir()` when neither the
/// `REPRO_PROFILE` env var nor `ServiceConfig::profile_path` names one.
pub const DEFAULT_PROFILE_FILE: &str = "repro_calibration.json";

/// Derived split thresholds are clamped into this range: below ~512 KiB a
/// cross-shard split can't beat the in-shard parallel path on any host we
/// model, above 64 MiB the threshold would never fire in practice.
pub const SPLIT_MIN_CLAMP: (u64, u64) = (512 << 10, 64 << 20);

/// Safety factor between the projected worst-case chunk service time and
/// the calibrated wedge threshold — generous enough that scheduling noise
/// never shoots a healthy worker.
pub const WEDGE_SAFETY_FACTOR: f64 = 50.0;

/// Floor for a calibrated wedge threshold (µs): never declare a worker
/// wedged faster than this, whatever the projection says.
pub const WEDGE_FLOOR_US: u64 = 100_000;

/// Process-global count of profile files rejected as corrupt, stale, or
/// version-mismatched (surfaced as `ServiceStats::profile_rejected`).
static REJECTED: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-global rejected-profile counter.
pub fn rejected_count() -> u64 {
    REJECTED.load(Ordering::Relaxed)
}

/// Count one rejected profile file.
pub fn note_rejected() {
    REJECTED.fetch_add(1, Ordering::Relaxed);
}

/// A measured machine calibration: everything the planner derives
/// thresholds from, in one versioned, serializable snapshot.
///
/// Index conventions match `engine::autotune`: precision 0 = f32,
/// 1 = f64; size class 0 = L1, 1 = LLC, 2 = MEM; accuracy tier
/// 0 = naive, 1 = kahan, 2 = dot2, 3 = exact.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationProfile {
    /// schema version ([`PROFILE_VERSION`])
    pub version: u64,
    /// identity of the machine the numbers were measured on; a profile
    /// loaded on a different machine is STALE and rejected
    pub machine: String,
    /// total worker threads across all shards at measure time
    pub threads: usize,
    /// NUMA shards at measure time
    pub shards: usize,
    /// measured streaming load bandwidth, GB/s
    pub mem_bw_gbs: f64,
    /// fixed fan-out + compensated-merge cost of one chunked parallel
    /// dot, µs (the per-request cost a split must amortize)
    pub split_fixed_us: f64,
    /// single-core Kahan-winner throughput, GB/s, `[precision][class]`
    pub kernel_gbs: [[f64; 3]; 2],
    /// ECM-predicted saturation cores `[precision][class]`; 0 = the
    /// class does not saturate
    pub sat_cores: [[u32; 3]; 2],
    /// observed-saturation correction factors `[precision][class]`
    /// (the autotuner's `note_saturation` state, persisted)
    pub sat_scale: [[f64; 3]; 2],
    /// measured f32 kahan/naive throughput ratio per class (≥ ~0.95
    /// means compensation is free there — the auto-upgrade predicate)
    pub kahan_vs_naive: [f64; 3],
    /// measured f32 dot2/naive throughput ratio per class
    pub dot2_vs_naive: [f64; 3],
    /// autotuned winner kernel name `[precision][class][tier]`
    pub winners: [[[String; 4]; 3]; 2],
    /// winner probe cycles `[precision][class][tier]` (0 for exact)
    pub probe_cy: [[[f64; 4]; 3]; 2],
    /// fused batch kernel name `[precision][class][tier]`; "" = serial
    pub batches: [[[String; 4]; 3]; 2],
}

const PREC_SFX: [&str; 2] = ["sp", "dp"];
const CLASS_SFX: [&str; 3] = ["l1", "llc", "mem"];

impl CalibrationProfile {
    /// One-shot measurement pass over the running process: reads the
    /// autotuner's calibrated table (probing it on first use), the
    /// host's ECM verdict (which already measured the load bandwidth),
    /// and times the fixed fan-out cost of a chunked dot. Cheap relative
    /// to first-use calibration itself — everything expensive is shared
    /// with the caches the serving path warms anyway.
    pub fn measure() -> CalibrationProfile {
        let table = dispatch();
        let verdict = host_verdict();
        let host = detect_host_cached();
        let ghz = calibrate_tsc_ghz_cached().max(0.1);
        let topo = topology_cached();
        let threads: usize = topo.nodes.iter().map(|n| n.cpus.len().max(1)).sum();
        let mem_bw_gbs = match verdict.source {
            ModelSource::Detected { measured_bw_gbs } => measured_bw_gbs,
            ModelSource::Preset(_) => verdict.machine.memory.load_bw_gbs,
        };

        let mut kernel_gbs = [[0.0f64; 3]; 2];
        let mut sat_scale = [[1.0f64; 3]; 2];
        let mut winners: [[[String; 4]; 3]; 2] = Default::default();
        let mut probe_cy = [[[0.0f64; 4]; 3]; 2];
        let mut batches: [[[String; 4]; 3]; 2] = Default::default();
        let mut kahan_vs_naive = [0.0f64; 3];
        let mut dot2_vs_naive = [0.0f64; 3];
        for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
            for (ci, class) in SizeClass::ALL.into_iter().enumerate() {
                let c = table.choice(prec, class);
                // probe cycles → GB/s: bytes × GHz / cycles (probe_bytes
                // is the total working set of one invocation)
                let kahan_cy = c.probe_cy(Accuracy::Kahan);
                if kahan_cy > 0.0 {
                    kernel_gbs[pi][ci] = table.probe_bytes[ci] as f64 * ghz / kahan_cy;
                }
                sat_scale[pi][ci] = table.sat_scale(prec, class);
                for acc in Accuracy::ALL {
                    let ti = acc_index(acc);
                    winners[pi][ci][ti] = c.winner(acc).name.to_string();
                    probe_cy[pi][ci][ti] = c.probe_cy(acc);
                    batches[pi][ci][ti] =
                        c.batch(acc).fused.map(|b| b.name.to_string()).unwrap_or_default();
                }
                if pi == prec_index(Precision::Sp) {
                    let naive_cy = c.probe_cy(Accuracy::Naive);
                    if naive_cy > 0.0 {
                        if kahan_cy > 0.0 {
                            kahan_vs_naive[ci] = naive_cy / kahan_cy;
                        }
                        let dot2_cy = c.probe_cy(Accuracy::Dot2);
                        if dot2_cy > 0.0 {
                            dot2_vs_naive[ci] = naive_cy / dot2_cy;
                        }
                    }
                }
            }
        }

        CalibrationProfile {
            version: PROFILE_VERSION,
            machine: host.name.to_string(),
            threads,
            shards: topo.nodes.len().max(1),
            mem_bw_gbs,
            split_fixed_us: measure_split_fixed_us(ghz),
            kernel_gbs,
            sat_cores: verdict.sat_cores,
            sat_scale,
            kahan_vs_naive,
            dot2_vs_naive,
            winners,
            probe_cy,
            batches,
        }
    }

    /// Serialize to the versioned flat-key JSON format (hand-rolled like
    /// the BENCH artifacts). Round-trips through [`CalibrationProfile::parse`].
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str(&format!("  \"profile\": \"{PROFILE_MAGIC}\",\n"));
        s.push_str(&format!("  \"version\": {},\n", self.version));
        s.push_str(&format!("  \"machine\": \"{}\",\n", escape(&self.machine)));
        s.push_str(&format!("  \"threads\": {},\n", self.threads));
        s.push_str(&format!("  \"shards\": {},\n", self.shards));
        s.push_str(&format!("  \"mem_bw_gbs\": {},\n", fnum(self.mem_bw_gbs)));
        s.push_str(&format!("  \"split_fixed_us\": {},\n", fnum(self.split_fixed_us)));
        for pi in 0..2 {
            s.push_str(&format!(
                "  \"kernel_gbs_{}\": {},\n",
                PREC_SFX[pi],
                num_array(&self.kernel_gbs[pi])
            ));
            s.push_str(&format!(
                "  \"sat_cores_{}\": [{}, {}, {}],\n",
                PREC_SFX[pi],
                self.sat_cores[pi][0],
                self.sat_cores[pi][1],
                self.sat_cores[pi][2]
            ));
            s.push_str(&format!(
                "  \"sat_scale_{}\": {},\n",
                PREC_SFX[pi],
                num_array(&self.sat_scale[pi])
            ));
        }
        s.push_str(&format!("  \"kahan_vs_naive\": {},\n", num_array(&self.kahan_vs_naive)));
        s.push_str(&format!("  \"dot2_vs_naive\": {},\n", num_array(&self.dot2_vs_naive)));
        for pi in 0..2 {
            for ci in 0..3 {
                let sfx = format!("{}_{}", PREC_SFX[pi], CLASS_SFX[ci]);
                s.push_str(&format!(
                    "  \"winners_{sfx}\": {},\n",
                    str_array(&self.winners[pi][ci])
                ));
                s.push_str(&format!(
                    "  \"probe_cy_{sfx}\": {},\n",
                    num_array(&self.probe_cy[pi][ci])
                ));
                let last = pi == 1 && ci == 2;
                s.push_str(&format!(
                    "  \"batch_{sfx}\": {}{}\n",
                    str_array(&self.batches[pi][ci]),
                    if last { "" } else { "," }
                ));
            }
        }
        s.push_str("}\n");
        s
    }

    /// Parse the flat-key JSON format. Structural validation only (shape,
    /// magic, version, plausibility); host staleness is
    /// [`CalibrationProfile::validate_for_host`]'s job. Never panics —
    /// any malformed input is an `Err` describing the first problem.
    pub fn parse(text: &str) -> Result<CalibrationProfile, String> {
        if json_str(text, "profile").as_deref() != Some(PROFILE_MAGIC) {
            return Err("not a repro_calibration profile".to_string());
        }
        let version = json_num(text, "version").ok_or("missing version")? as u64;
        if version != PROFILE_VERSION {
            return Err(format!(
                "version mismatch: file v{version}, supported v{PROFILE_VERSION}"
            ));
        }
        let machine = json_str(text, "machine").ok_or("missing machine")?;
        let threads = json_num(text, "threads").ok_or("missing threads")? as usize;
        let shards = json_num(text, "shards").ok_or("missing shards")? as usize;
        if threads == 0 || shards == 0 || threads > 1 << 20 || shards > 1 << 16 {
            return Err(format!("implausible topology: threads={threads} shards={shards}"));
        }
        let mem_bw_gbs = json_num(text, "mem_bw_gbs").ok_or("missing mem_bw_gbs")?;
        let split_fixed_us = json_num(text, "split_fixed_us").ok_or("missing split_fixed_us")?;
        if !(0.0..1e7).contains(&split_fixed_us) || !(0.0..1e5).contains(&mem_bw_gbs) {
            return Err("implausible bandwidth/fixed-cost figures".to_string());
        }
        let mut p = CalibrationProfile {
            version,
            machine,
            threads,
            shards,
            mem_bw_gbs,
            split_fixed_us,
            kernel_gbs: [[0.0; 3]; 2],
            sat_cores: [[0; 3]; 2],
            sat_scale: [[1.0; 3]; 2],
            kahan_vs_naive: [0.0; 3],
            dot2_vs_naive: [0.0; 3],
            winners: Default::default(),
            probe_cy: [[[0.0; 4]; 3]; 2],
            batches: Default::default(),
        };
        for pi in 0..2 {
            let kg = json_num_array(text, &format!("kernel_gbs_{}", PREC_SFX[pi]), 3)?;
            let sc = json_num_array(text, &format!("sat_cores_{}", PREC_SFX[pi]), 3)?;
            let ss = json_num_array(text, &format!("sat_scale_{}", PREC_SFX[pi]), 3)?;
            for ci in 0..3 {
                if !(0.0..1e6).contains(&kg[ci]) || !(0.0..1e5).contains(&sc[ci]) {
                    return Err("implausible kernel throughput / saturation".to_string());
                }
                p.kernel_gbs[pi][ci] = kg[ci];
                p.sat_cores[pi][ci] = sc[ci] as u32;
                p.sat_scale[pi][ci] = ss[ci].clamp(0.25, 4.0);
            }
        }
        let kn = json_num_array(text, "kahan_vs_naive", 3)?;
        let dn = json_num_array(text, "dot2_vs_naive", 3)?;
        for ci in 0..3 {
            if !(0.0..1e3).contains(&kn[ci]) || !(0.0..1e3).contains(&dn[ci]) {
                return Err("implausible accuracy-tier ratios".to_string());
            }
            p.kahan_vs_naive[ci] = kn[ci];
            p.dot2_vs_naive[ci] = dn[ci];
        }
        for pi in 0..2 {
            for ci in 0..3 {
                let sfx = format!("{}_{}", PREC_SFX[pi], CLASS_SFX[ci]);
                let w = json_str_array(text, &format!("winners_{sfx}"), 4)?;
                let pc = json_num_array(text, &format!("probe_cy_{sfx}"), 4)?;
                let bt = json_str_array(text, &format!("batch_{sfx}"), 4)?;
                for ti in 0..4 {
                    p.winners[pi][ci][ti] = w[ti].clone();
                    p.probe_cy[pi][ci][ti] = pc[ti].max(0.0);
                    p.batches[pi][ci][ti] = bt[ti].clone();
                }
            }
        }
        Ok(p)
    }

    /// STALE check: a profile measured on a different machine must not
    /// drive this one's thresholds.
    pub fn validate_for_host(&self) -> Result<(), String> {
        let host = detect_host_cached().name;
        if self.machine != host {
            return Err(format!(
                "stale profile: measured on '{}', running on '{host}'",
                self.machine
            ));
        }
        Ok(())
    }

    /// Load + parse + staleness-check one profile file. Every rejection
    /// path (unreadable, corrupt, version-mismatched, stale) increments
    /// the process-global [`rejected_count`] and returns `Err` — callers
    /// fall back to defaults, they never panic.
    pub fn load(path: &Path) -> Result<CalibrationProfile, String> {
        let fail = |m: String| {
            note_rejected();
            Err(m)
        };
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return fail(format!("unreadable profile {}: {e}", path.display())),
        };
        let p = match Self::parse(&text) {
            Ok(p) => p,
            Err(e) => return fail(format!("corrupt profile {}: {e}", path.display())),
        };
        if let Err(e) = p.validate_for_host() {
            return fail(e);
        }
        Ok(p)
    }

    /// Persist to `path` (atomically enough for our purposes: write to a
    /// sibling temp file, then rename).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json())
            .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| format!("renaming to {}: {e}", path.display()))
    }

    /// Effective saturation cores for one `[precision][class]` cell: the
    /// ECM prediction times the persisted observed correction;
    /// `usize::MAX` where the class does not saturate.
    pub fn effective_sat(&self, pi: usize, ci: usize) -> usize {
        let n = self.sat_cores[pi][ci];
        if n == 0 {
            usize::MAX
        } else {
            ((n as f64 * self.sat_scale[pi][ci]).round() as usize).max(1)
        }
    }

    /// The measured split crossover: the smallest request (total bytes,
    /// both streams) for which splitting across every shard is projected
    /// faster than serving on the single widest shard, i.e. where the
    /// split's measured fixed cost amortizes:
    ///
    /// ```text
    ///   B / bw_one  =  B / bw_all + fixed   ⇒
    ///   B = fixed · bw_one · bw_all / (bw_all − bw_one)
    /// ```
    ///
    /// with `bw_one` = per-core throughput × min(widest shard, saturation)
    /// and `bw_all` = per-core throughput × min(total workers, saturation),
    /// minimized over the split-relevant classes (LLC, MEM) and both
    /// precisions, clamped into [`SPLIT_MIN_CLAMP`]. `None` when the
    /// topology can't gain from splitting (one shard, or saturation caps
    /// the split down to single-shard bandwidth) — callers keep the
    /// built-in 4 MiB default.
    pub fn derived_split_min_bytes(&self, shard_workers: &[usize]) -> Option<u64> {
        let total: usize = shard_workers.iter().sum();
        let widest = shard_workers.iter().copied().max().unwrap_or(0);
        if shard_workers.len() < 2 || widest == 0 || total <= widest {
            return None;
        }
        let fixed_secs = (self.split_fixed_us * 1e-6).max(0.0);
        let mut best: Option<f64> = None;
        for ci in [SizeClass::Llc.index(), SizeClass::Mem.index()] {
            for pi in 0..2 {
                let per_core = self.kernel_gbs[pi][ci];
                if per_core <= 0.0 {
                    continue;
                }
                let sat = self.effective_sat(pi, ci);
                let bw_one = per_core * widest.min(sat) as f64;
                let bw_all = per_core * total.min(sat) as f64;
                if bw_all <= bw_one * 1.01 {
                    // saturation gives the split no headroom in this class
                    continue;
                }
                let crossover = fixed_secs * 1e9 * (bw_one * bw_all) / (bw_all - bw_one);
                best = Some(best.map_or(crossover, |b: f64| b.min(crossover)));
            }
        }
        best.map(|b| (b.round() as u64).clamp(SPLIT_MIN_CLAMP.0, SPLIT_MIN_CLAMP.1))
    }

    /// The planner-facing slice of this profile: projected one-shard and
    /// all-shard bandwidths per `[precision][class]` (for deadline-aware
    /// routing) plus the measured accuracy-tier ratios (for free
    /// upgrades). Pure arithmetic over the measured numbers.
    pub fn plan_calibration(&self, shard_workers: &[usize]) -> PlanCalibration {
        let total: usize = shard_workers.iter().sum::<usize>().max(1);
        let widest = shard_workers.iter().copied().max().unwrap_or(1).max(1);
        let mut shard_gbs = [[0.0f64; 3]; 2];
        let mut split_gbs = [[0.0f64; 3]; 2];
        for pi in 0..2 {
            for ci in 0..3 {
                let per_core = self.kernel_gbs[pi][ci];
                if per_core <= 0.0 {
                    continue;
                }
                let sat = self.effective_sat(pi, ci);
                shard_gbs[pi][ci] = per_core * widest.min(sat) as f64;
                split_gbs[pi][ci] = per_core * total.min(sat) as f64;
            }
        }
        PlanCalibration {
            shard_gbs,
            split_gbs,
            split_fixed_us: self.split_fixed_us,
            kahan_vs_naive: self.kahan_vs_naive,
            dot2_vs_naive: self.dot2_vs_naive,
        }
    }

    /// Calibrated worker wedge threshold (µs): the projected service time
    /// of one worker's chunk of the largest request the size classifier
    /// models (64 MiB of streams), at the slowest measured per-core
    /// throughput, times [`WEDGE_SAFETY_FACTOR`], floored at
    /// [`WEDGE_FLOOR_US`]. Returns 0 (= detection off) when the profile
    /// has no usable throughput figure.
    pub fn worker_wedge_default_us(&self) -> u64 {
        let mut slowest = f64::INFINITY;
        for row in &self.kernel_gbs {
            for &g in row {
                if g > 0.0 {
                    slowest = slowest.min(g);
                }
            }
        }
        if !slowest.is_finite() {
            return 0;
        }
        let chunk_bytes = (64u64 << 20) as f64;
        // GB/s → bytes/µs is ×1000
        let t_us = chunk_bytes / (slowest * 1000.0);
        ((t_us * WEDGE_SAFETY_FACTOR).ceil() as u64).max(WEDGE_FLOOR_US)
    }

    /// Calibrated lane wedge threshold: a submitter lane legitimately
    /// waits on whole requests (several chunks deep), so its threshold is
    /// a multiple of the worker's. 0 when the worker threshold is 0.
    pub fn lane_wedge_default_us(&self) -> u64 {
        self.worker_wedge_default_us().saturating_mul(4)
    }

    /// Seed the live dispatch table's saturation corrections from this
    /// profile (the inverse of [`CalibrationProfile::measure`] snapshotting
    /// them). Concurrency only — never bits.
    pub fn seed_saturation(&self, table: &DispatchTable) {
        for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
            for (ci, class) in SizeClass::ALL.into_iter().enumerate() {
                table.set_sat_scale(prec, class, self.sat_scale[pi][ci]);
            }
        }
    }
}

/// Fixed fan-out + merge cost of one chunked parallel dot (µs): round-trip
/// a tiny two-chunk dot through a dedicated two-worker pool — the work
/// itself is negligible, so the median is the handoff + collect + fold
/// overhead a split pays per shard.
fn measure_split_fixed_us(ghz: f64) -> f64 {
    use super::parallel::{parallel_dot_f32, WorkerPool};
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    let v = vec![1.0f32; 1024];
    let a = Arc::new(bufs.admit(&v));
    let b = Arc::new(bufs.admit(&v));
    let f = super::kernel_for_f32(Accuracy::Kahan, (2 * v.len() * 4) as u64);
    let m = measure_adaptive(200_000.0, 5, || parallel_dot_f32(&pool, f, &a, &b, 2));
    // cycles → µs at the calibrated clock
    (m.median_cy / (ghz * 1000.0)).max(0.1)
}

/// The profile path this process resolves to: the `REPRO_PROFILE` env var
/// when set (`off` / `0` / `none` / empty disables profiles entirely),
/// else `$TMPDIR/`[`DEFAULT_PROFILE_FILE`].
pub fn resolved_path() -> Option<PathBuf> {
    match std::env::var("REPRO_PROFILE") {
        Ok(v) => {
            let v = v.trim().to_string();
            if v.is_empty() || v == "off" || v == "0" || v == "none" {
                None
            } else {
                Some(PathBuf::from(v))
            }
        }
        Err(_) => Some(std::env::temp_dir().join(DEFAULT_PROFILE_FILE)),
    }
}

/// An explicitly installed profile (calibrate CLI, benches, a service
/// with `profile_path` set) takes precedence over the disk-loaded one —
/// and crucially, installation still works after the disk decision was
/// made: the measurement pass itself touches `dispatch()` (which consults
/// [`host_profile`]), so a lazy measure-then-install would otherwise
/// always lose the race against its own measurement.
static INSTALLED_PROFILE: OnceLock<CalibrationProfile> = OnceLock::new();
static DISK_PROFILE: OnceLock<Option<CalibrationProfile>> = OnceLock::new();

/// The process-wide profile: an installed one if present, else the file
/// at [`resolved_path`], loaded (NOT measured) on first use. Load-only by
/// design: a fresh host with no file gets `None` and built-in defaults —
/// deterministic for tests and cold CI runners. The one-shot measurement
/// pass runs only where explicitly asked for: `repro calibrate`, the
/// benches, or a service started with `ServiceConfig::profile_path` set
/// (which measures-and-caches lazily).
pub fn host_profile() -> Option<&'static CalibrationProfile> {
    if let Some(p) = INSTALLED_PROFILE.get() {
        return Some(p);
    }
    DISK_PROFILE
        .get_or_init(|| {
            let path = resolved_path()?;
            if !path.exists() {
                return None;
            }
            CalibrationProfile::load(&path).ok()
        })
        .as_ref()
}

/// Install `p` as the process-wide profile (benches and the calibrate CLI
/// use this so the global engine they then construct plans on the freshly
/// measured numbers; the service's lazy `profile_path` measurement does
/// too). Wins over any disk-loaded profile, but only once: a second
/// installation returns `false` and changes nothing — consumers that
/// already planned on the first profile must never see numbers move under
/// them.
pub fn install_host_profile(p: CalibrationProfile) -> bool {
    INSTALLED_PROFILE.set(p).is_ok()
}

// ---- minimal tolerant flat-JSON field extraction ------------------------

fn escape(s: &str) -> String {
    s.chars().filter(|c| *c != '"' && *c != '\\' && !c.is_control()).collect()
}

/// Format one f64 for emission (NaN/inf would corrupt the file → 0).
fn fnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0".to_string()
    }
}

fn num_array(xs: &[f64]) -> String {
    let body: Vec<String> = xs.iter().map(|&x| fnum(x)).collect();
    format!("[{}]", body.join(", "))
}

fn str_array(xs: &[String]) -> String {
    let body: Vec<String> = xs.iter().map(|x| format!("\"{}\"", escape(x))).collect();
    format!("[{}]", body.join(", "))
}

/// The raw text after `"key":`, up to the end of its value region.
fn value_region<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":");
    let at = text.find(&needle)?;
    Some(text[at + needle.len()..].trim_start())
}

fn json_num(text: &str, key: &str) -> Option<f64> {
    let rest = value_region(text, key)?;
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '-' || c == '+' || c == '.' || c == 'e' || c == 'E'))
        .unwrap_or(rest.len());
    rest[..end].parse::<f64>().ok().filter(|v| v.is_finite())
}

fn json_str(text: &str, key: &str) -> Option<String> {
    let rest = value_region(text, key)?;
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

fn json_num_array(text: &str, key: &str, want: usize) -> Result<Vec<f64>, String> {
    let rest = value_region(text, key).ok_or_else(|| format!("missing {key}"))?;
    let rest = rest.strip_prefix('[').ok_or_else(|| format!("{key}: not an array"))?;
    let end = rest.find(']').ok_or_else(|| format!("{key}: unterminated array"))?;
    let vals: Result<Vec<f64>, _> =
        rest[..end].split(',').map(|s| s.trim().parse::<f64>()).collect();
    let vals = vals.map_err(|e| format!("{key}: {e}"))?;
    if vals.len() != want || vals.iter().any(|v| !v.is_finite()) {
        return Err(format!("{key}: expected {want} finite numbers"));
    }
    Ok(vals)
}

fn json_str_array(text: &str, key: &str, want: usize) -> Result<Vec<String>, String> {
    let rest = value_region(text, key).ok_or_else(|| format!("missing {key}"))?;
    let rest = rest.strip_prefix('[').ok_or_else(|| format!("{key}: not an array"))?;
    let end = rest.find(']').ok_or_else(|| format!("{key}: unterminated array"))?;
    let mut out = Vec::with_capacity(want);
    for part in rest[..end].split(',') {
        let part = part.trim();
        let inner = part
            .strip_prefix('"')
            .and_then(|p| p.strip_suffix('"'))
            .ok_or_else(|| format!("{key}: not a string array"))?;
        out.push(inner.to_string());
    }
    if out.len() != want {
        return Err(format!("{key}: expected {want} strings"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fully synthetic profile for threshold-math tests: 10 GB/s per
    /// core everywhere, no saturation, 100 µs fixed split cost.
    fn synthetic() -> CalibrationProfile {
        CalibrationProfile {
            version: PROFILE_VERSION,
            machine: "test-machine".to_string(),
            threads: 4,
            shards: 2,
            mem_bw_gbs: 40.0,
            split_fixed_us: 100.0,
            kernel_gbs: [[10.0; 3]; 2],
            sat_cores: [[0; 3]; 2],
            sat_scale: [[1.0; 3]; 2],
            kahan_vs_naive: [0.5, 0.9, 0.99],
            dot2_vs_naive: [0.4, 0.8, 0.97],
            winners: Default::default(),
            probe_cy: [[[0.0; 4]; 3]; 2],
            batches: Default::default(),
        }
    }

    #[test]
    fn json_round_trip_is_lossless_enough() {
        let mut p = synthetic();
        p.winners[0][0][1] = "kahan_avx2_f32".to_string();
        p.probe_cy[0][0][1] = 123.456;
        p.batches[0][0][1] = "kahan_avx2_f32_b8".to_string();
        let back = CalibrationProfile::parse(&p.to_json()).expect("round trip");
        assert_eq!(back.machine, p.machine);
        assert_eq!(back.threads, p.threads);
        assert_eq!(back.shards, p.shards);
        assert_eq!(back.winners[0][0][1], "kahan_avx2_f32");
        assert_eq!(back.batches[0][0][1], "kahan_avx2_f32_b8");
        assert!((back.probe_cy[0][0][1] - 123.456).abs() < 1e-3);
        assert!((back.split_fixed_us - 100.0).abs() < 1e-6);
        assert!((back.kahan_vs_naive[2] - 0.99).abs() < 1e-6);
    }

    #[test]
    fn parse_rejects_garbage_and_mismatched_versions_without_panic() {
        let before = rejected_count();
        for bad in [
            "",
            "not json at all",
            "{\"profile\": \"something_else\"}",
            "{\"profile\": \"repro_calibration\"}",
            "{\"profile\": \"repro_calibration\", \"version\": 9999}",
        ] {
            assert!(CalibrationProfile::parse(bad).is_err(), "{bad:?} must be rejected");
        }
        // truncated real profile: every prefix parses to Err, never panics
        let good = synthetic().to_json();
        for cut in [10usize, 100, 300, good.len() - 5] {
            assert!(CalibrationProfile::parse(&good[..cut]).is_err());
        }
        // pure parse never counts — only `load` does
        assert_eq!(rejected_count(), before);
    }

    #[test]
    fn load_counts_every_rejection_flavor() {
        let dir = std::env::temp_dir();
        let before = rejected_count();
        // unreadable
        assert!(CalibrationProfile::load(&dir.join("repro_profile_does_not_exist.json")).is_err());
        // corrupt
        let corrupt = dir.join("repro_profile_test_corrupt.json");
        std::fs::write(&corrupt, "{ nope").unwrap();
        assert!(CalibrationProfile::load(&corrupt).is_err());
        // stale: valid file, wrong machine
        let stale = dir.join("repro_profile_test_stale.json");
        std::fs::write(&stale, synthetic().to_json()).unwrap();
        assert!(CalibrationProfile::load(&stale).is_err(), "wrong-machine profile is stale");
        assert_eq!(rejected_count(), before + 3);
        let _ = std::fs::remove_file(&corrupt);
        let _ = std::fs::remove_file(&stale);
    }

    #[test]
    fn save_load_round_trips_for_the_current_host() {
        let mut p = synthetic();
        p.machine = detect_host_cached().name.to_string();
        let path = std::env::temp_dir().join("repro_profile_test_roundtrip.json");
        p.save(&path).expect("save");
        let back = CalibrationProfile::load(&path).expect("load what we saved");
        assert_eq!(back, p);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn split_crossover_math_and_clamps() {
        let p = synthetic();
        // two shards × 2 workers, no saturation: bw_one = 20 GB/s,
        // bw_all = 40 GB/s → B = 100 µs × 40 GB/s = 4 MB — mid-range.
        let b = p.derived_split_min_bytes(&[2, 2]).expect("split gains");
        let expect = 100.0e-6 * 1e9 * (20.0 * 40.0) / 20.0;
        assert!((b as f64 - expect).abs() / expect < 0.01, "b={b} expect={expect}");
        // a huge fixed cost clamps high, a zero fixed cost clamps low
        let mut hi = p.clone();
        hi.split_fixed_us = 1e6;
        assert_eq!(hi.derived_split_min_bytes(&[2, 2]), Some(SPLIT_MIN_CLAMP.1));
        let mut lo = p.clone();
        lo.split_fixed_us = 0.0;
        assert_eq!(lo.derived_split_min_bytes(&[2, 2]), Some(SPLIT_MIN_CLAMP.0));
        // one shard can't split; saturation at the widest shard's width
        // leaves no headroom either
        assert_eq!(p.derived_split_min_bytes(&[4]), None);
        let mut sat = p.clone();
        sat.sat_cores = [[2; 3]; 2];
        assert_eq!(sat.derived_split_min_bytes(&[2, 2]), None);
    }

    #[test]
    fn plan_calibration_projects_saturation_capped_bandwidth() {
        let mut p = synthetic();
        p.sat_cores = [[0, 0, 3], [0, 0, 3]];
        let c = p.plan_calibration(&[2, 2]);
        // unsaturated classes scale with workers
        assert!((c.shard_gbs[0][1] - 20.0).abs() < 1e-9);
        assert!((c.split_gbs[0][1] - 40.0).abs() < 1e-9);
        // MEM saturates at 3 cores: split bandwidth caps there
        assert!((c.split_gbs[0][2] - 30.0).abs() < 1e-9);
        assert!((c.shard_gbs[0][2] - 20.0).abs() < 1e-9);
        assert!((c.split_fixed_us - 100.0).abs() < 1e-9);
    }

    #[test]
    fn wedge_defaults_scale_with_throughput_and_floor() {
        let p = synthetic();
        // 64 MiB at 10 GB/s ≈ 6.7 ms → ×50 ≈ 335 ms, above the floor
        let w = p.worker_wedge_default_us();
        assert!(w >= WEDGE_FLOOR_US, "{w}");
        assert!(w < 10_000_000, "{w}");
        assert_eq!(p.lane_wedge_default_us(), w * 4);
        // no throughput figures → 0 = off
        let mut empty = p.clone();
        empty.kernel_gbs = [[0.0; 3]; 2];
        assert_eq!(empty.worker_wedge_default_us(), 0);
        assert_eq!(empty.lane_wedge_default_us(), 0);
        // a very fast machine still floors at WEDGE_FLOOR_US
        let mut fast = p.clone();
        fast.kernel_gbs = [[1e5; 3]; 2];
        assert_eq!(fast.worker_wedge_default_us(), WEDGE_FLOOR_US);
    }

    #[test]
    fn effective_sat_applies_persisted_corrections() {
        let mut p = synthetic();
        p.sat_cores[0][2] = 4;
        p.sat_scale[0][2] = 0.5;
        assert_eq!(p.effective_sat(0, 2), 2);
        p.sat_scale[0][2] = 4.0;
        assert_eq!(p.effective_sat(0, 2), 16);
        assert_eq!(p.effective_sat(0, 0), usize::MAX, "0 = does not saturate");
    }
}
