//! Persistent parallel dot engine — the allocation-free request hot path.
//!
//! The paper's headline is that a Kahan-compensated dot is (nearly) free
//! once SIMD, unrolling and bandwidth saturation are exploited. This module
//! is the serving-side consequence: keep everything that is expensive to
//! set up — aligned buffers, pinned threads, kernel selection — alive
//! across requests, so the steady-state cost of a served dot is the
//! streaming cost the paper models and nothing else.
//!
//! # Architecture: plan → admit/shed → govern → route → shard → pool → partition → kernel → merge — supervised end to end
//!
//! ```text
//!   clients (any thread)
//!   ──► DotClient routes: pooled → home-shard lane, fresh → round-robin
//!        │
//!        ▼
//!   ┌─ overload protection (coordinator::service admission gate) ───────┐
//!   │ deadline requests are SHED, never blocked: PlanPolicy::shed       │
//!   │ projects the lane's queue wait (live depth × histogram mean       │
//!   │ service time) and rejects with a clean "shed: …" error when the   │
//!   │ lane is full or the projection exceeds the deadline; per-client   │
//!   │ in-flight caps (fair lanes) shed the greedy client, not the       │
//!   │ quiet one. A shed rejects the WHOLE request — served requests     │
//!   │ are bit-identical with or without shedding. Deadline-free         │
//!   │ requests keep the old contract: a full lane blocks the sender,    │
//!   │ with the stall counted and its microseconds folded into the       │
//!   │ queue-wait histogram (ServiceStats::{shed, fair_sheds,            │
//!   │ stalled_us, queue_wait, service_time})                            │
//!   └───────────────────────────────────────────────────────────────────┘
//!        │  bounded per-shard queues (back-pressure: a full lane blocks
//!        │  only deadline-free senders; stalls counted in ServiceStats)
//!        ▼
//!   submitter threads, one per shard (coordinator::service router pool —
//!   independent requests execute concurrently on different shards).
//!   Each submitter drains its queue greedily: k ≥ 2 queued small dots
//!   become ONE engine batch (dot_batch_on), a burst of admissions ONE
//!   worker pass (admit_local_many) — request overhead amortizes like the
//!   paper amortizes loop overhead, and bits never change (the plan
//!   module's "Batching invariant"). When a window is configured, a lane
//!   holding a short run may wait for more — but only when the planner
//!   says the fused kernel wins at the projected batch size
//!        │
//!        ▼
//!   ┌─ engine::profile — persisted measured calibration ────────────────┐
//!   │ a versioned on-disk CalibrationProfile (REPRO_PROFILE /           │
//!   │ ServiceConfig::profile_path; `repro calibrate --write`) carries   │
//!   │ what a measurement pass learned: per-(precision, size class)      │
//!   │ kernel throughput and winners, saturation corrections, the split  │
//!   │ path's fixed fan-out cost, and the measured kahan/dot2-vs-naive   │
//!   │ ratios. On load it seeds the DispatchTable (cold start ≈ warmed   │
//!   │ up), derives split_min_bytes from the measured crossover, arms    │
//!   │ deadline-aware routing and free accuracy upgrades, and calibrates │
//!   │ the supervision wedge thresholds. Corrupt/stale/mismatched files  │
//!   │ are rejected whole (profile_rejected stat) and every default      │
//!   │ stands — a profile can tune thresholds and concurrency, NEVER     │
//!   │ chunk geometry or bits ("# Calibration" in the plan module)       │
//!   └───────────────────────────────────────────────────────────────────┘
//!        │
//!        ▼
//!   ┌─ engine::plan — the PURE planning layer ──────────────────────────┐
//!   │ PlanPolicy (autotuned DispatchTable + topology + ServiceConfig)   │
//!   │ compiles every request into a DotPlan: inline / one-shard         │
//!   │ parallel / fused batch with cutoff / weighted split with flat     │
//!   │ compensated merge. Every threshold below is a planner call. The   │
//!   │ plan carries the requested ACCURACY tier (naive / kahan / dot2 /  │
//!   │ exact) — the dispatch table holds one winner per tier per cell,   │
//!   │ and exact always plans Inline (scalar expansion, no SIMD claim).  │
//!   │ With a calibration armed it also projects service times: a        │
//!   │ deadline request whose parallel projection blows the deadline     │
//!   │ while the split projection fits is PROMOTED to Split (same chunk  │
//!   │ geometry — bit-identical), and a naive request whose measured     │
//!   │ class ratio says compensation is free upgrades to kahan           │
//!   └───────────────────────────────────────────────────────────────────┘
//!        │
//!        ▼
//!   ┌─ ECM governance (crate::ecm::governance) ─────────────────────────┐
//!   │ the host's EcmModel predicts the bandwidth saturation point n_S   │
//!   │ per (precision, size class); fan-out is capped there (autotuner-  │
//!   │ corrected, clamped to the realized worker count). A cap changes   │
//!   │ CONCURRENCY ONLY: chunk geometry stays planner-derived, so capped │
//!   │ runs are bit-identical to uncapped ones, and the freed workers    │
//!   │ serve other lanes' requests concurrently (see "# ECM governance"  │
//!   │ in the plan module)                                               │
//!   └───────────────────────────────────────────────────────────────────┘
//!        │
//!        ▼
//!                  ┌──────────────────────────────────────────────────┐
//!   request(a, b)  │ ShardedEngine (one shard per NUMA domain;        │
//!   ─────────────► │ single-node hosts degrade to exactly one shard)  │
//!                  │  0. route  : pooled streams go to their home     │
//!                  │              shard; fresh requests round-robin;  │
//!                  │              very large dots split across every  │
//!                  │              shard on global chunk boundaries    │
//!                  │ ┌──────────────────────────────────────────────┐ │
//!                  │ │ DotEngine (per shard: own BufferPool + own   │ │
//!                  │ │ WorkerPool pinned to the domain's CPU list)  │ │
//!                  │ │  1. pool   : admit streams into recycled     │ │
//!                  │ │              64-byte-aligned NUMA-local      │ │
//!                  │ │              buffers (zero heap allocation   │ │
//!                  │ │              at steady state)                │ │
//!                  │ │  2. partition: cut into cache-line-aligned,  │ │
//!                  │ │              balanced chunks (max−min ≤ one  │ │
//!                  │ │              cache line), one per worker     │ │
//!                  │ │  3. kernel : per chunk, the autotuned best   │ │
//!                  │ │              host SIMD kernel for (accuracy  │ │
//!                  │ │              tier, precision, size class)    │ │
//!                  │ │  4. merge  : compensated (Neumaier) fold of  │ │
//!                  │ │              per-chunk partials, chunk order │ │
//!                  │ └──────────────────────────────────────────────┘ │
//!                  │  5. merge  : the *same* compensated fold over    │
//!                  │              all shards' per-chunk partials in   │
//!                  │              global chunk order — one more       │
//!                  │              reduction level, same Kahan bound,  │
//!                  │              same bits for 1 or N shards         │
//!                  └──────────────────────────────────────────────────┘
//!
//!   ┌─ fault domains & supervision (cuts across every layer above) ─────┐
//!   │ the service's supervisor thread periodically sweeps all three     │
//!   │ fault domains and heals them without changing a single bit:       │
//!   │  * WORKERS — WorkerPool::supervise reaps dead threads (finished   │
//!   │    join handle) and wedged threads (stale heartbeat) and respawns │
//!   │    them re-pinned on the SAME queue (EngineStats::respawns /      │
//!   │    respawn_pin_failures); a dead worker's in-flight chunk job is  │
//!   │    dropped, so the chunk collector reports a clean "worker died"  │
//!   │    error — a recovery never fabricates a partial                  │
//!   │  * LANES — a dead or wedged submitter lane is restarted over the  │
//!   │    same bounded queue (ServiceStats::lane_restarts); queued       │
//!   │    requests are re-served by the replacement or cleanly errored,  │
//!   │    never silently dropped                                         │
//!   │  * SHARDS — a shard whose workers exhaust the respawn budget is   │
//!   │    QUARANTINED (ServiceStats::quarantines): dropped from fresh    │
//!   │    routing and re-weighted out of split shard-sets with chunk     │
//!   │    geometry unchanged, so quarantine never changes bits (see      │
//!   │    "# Fault domains" in the plan module); a periodic probe dot    │
//!   │    reinstates it once it serves again                             │
//!   │ Failures are reproducible: `--features faultinject` compiles      │
//!   │ seeded FaultPlan hooks (util::faults) into worker/chunk/lane      │
//!   │ sites, and the chaos suite (rust/tests/test_faults.rs) asserts    │
//!   │ no hangs, typed errors, bit-identical survivors, and recovery     │
//!   │ counters matching the injected schedule                           │
//!   └───────────────────────────────────────────────────────────────────┘
//! ```
//!
//! * [`pool`] — the recycling aligned buffer pool ([`BufferPool`]).
//! * [`parallel`] — the long-lived pinned worker pool ([`WorkerPool`]) and
//!   the chunked compensated reduction (`parallel_dot_*`).
//! * [`autotune`] — first-use micro-calibration of the kernel registry into
//!   a `(Precision, SizeClass)` dispatch table behind a `OnceLock`.
//! * [`plan`] — the pure request planner: one [`PlanPolicy`] holds every
//!   route/batch/split threshold, and every layer consumes its compiled
//!   [`DotPlan`]s instead of re-deriving decisions.
//! * [`profile`] — the persistent measured-calibration layer: a versioned
//!   on-disk [`CalibrationProfile`] seeds the dispatch table, derives the
//!   split threshold from the measured crossover, and arms the planner's
//!   deadline/upgrade projections ("# Calibration" in [`plan`]).
//! * [`topology`] — NUMA domain discovery (`/sys/devices/system/node`,
//!   with a single-node fallback when sysfs is absent).
//! * [`sharded`] — the multi-socket tier: [`ShardedEngine`] owns one
//!   [`DotEngine`] per NUMA domain and routes/splits requests across them.
//! * `crate::ecm::governance` — the ECM verdict for the detected host:
//!   predicted saturation cores per (precision, size class) become the
//!   worker caps this module and the planner enforce.
//!
//! # Length policy / Batching invariant
//!
//! Both contracts are documented once, next to [`DotPlan`] in the [`plan`]
//! module — the layer that now enforces them. Short form: dots compute
//! over `min(a.len(), b.len())` elements (mismatches are `debug_assert`ed
//! and rejected by the service before the engine), and batching never
//! changes bits (every batch path returns exactly the serial path's
//! value, property-tested in `rust/tests/test_batch.rs` and
//! `rust/tests/test_plan.rs`).
//!
//! # Accuracy
//!
//! Accuracy is a request dimension: every dot names its tier (Naive /
//! Kahan / Dot2 / Exact) and the engine serves it with the tier's
//! autotuned winner — see "# Accuracy tiers" in the [`plan`] module.
//! Within a compensated tier each chunk is a full compensated dot
//! (per-lane compensation folded by the kernel); the cross-chunk merge
//! reuses the registry's compensated fold. The parallel result therefore
//! keeps the tier's sequential error bound — Kahan's `O(u)·Σ|aᵢbᵢ|`,
//! Dot2's `u + O(u²)·cond` — for any chunk count; see the property tests
//! in `rust/tests/test_engine.rs` (random lengths, chunk counts, and
//! Ogita–Rump–Oishi ill-conditioned inputs). Exact-tier dots always run
//! inline on one worker and return the correctly rounded value.
//!
//! # Determinism
//!
//! Chunk boundaries depend only on `(n, worker count)` and partials merge
//! in chunk order, so results are bit-reproducible run to run for a fixed
//! engine configuration.
//!
//! # Who uses it
//!
//! * `coordinator::service` executes host-backend requests here through
//!   its per-shard submitter pool (the default backend; PJRT remains
//!   available behind `Backend::Pjrt`). Each submitter calls its own
//!   shard's engine directly; only above-`split_min_bytes` dots go
//!   through the sharded split path.
//! * `bench::threads::scaling_curve` reuses one [`WorkerPool`] across all
//!   thread counts instead of re-spawning per measurement.
//! * `benches/bench_engine.rs` records the engine-vs-spawn-per-call
//!   speedup into `BENCH_engine.json`.

pub mod autotune;
pub mod parallel;
pub mod plan;
pub mod pool;
pub mod profile;
pub mod sharded;
pub mod topology;

pub use autotune::{dispatch, BatchChoice, Choice, DispatchTable, SizeClass};
pub use plan::{DotPlan, DotRoute, PlanCalibration, PlanPolicy};
pub use parallel::{
    chunk_ranges, parallel_dot_capped_f32, parallel_dot_capped_f64, parallel_dot_f32,
    parallel_dot_f64, WorkerPool,
};
pub use pool::{BufferPool, PoolStats, PooledSlice};
pub use profile::{host_profile, install_host_profile, CalibrationProfile};
pub use sharded::{HomedSlice, ShardedConfig, ShardedEngine, ShardedStats, DEFAULT_SPLIT_MIN_BYTES};
pub use topology::{topology_cached, NumaNode, Topology};

use crate::bench::kernels::KernelFn;
use crate::isa::{Accuracy, Precision};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// worker threads; 0 = one per online CPU
    pub threads: usize,
    /// total working sets (both streams, bytes) below this run on the
    /// caller's thread directly over the caller's slices (zero copy, zero
    /// dispatch) — small dots don't amortize a hand-off
    pub parallel_cutoff_bytes: usize,
    /// consult the host's ECM verdict and cap parallel fan-out at the
    /// predicted saturation cores (MEM-class dots stop scaling once the
    /// memory bus saturates — extra workers only burn cores other
    /// requests could use). Capping changes concurrency only, never bits.
    pub governance: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: 0, parallel_cutoff_bytes: 256 * 1024, governance: true }
    }
}

/// Aggregate engine counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// dots served
    pub requests: u64,
    /// dots that took the chunked-parallel path
    pub parallel: u64,
    /// dots served through a batched execution path (`dot_batch_*` or a
    /// sharded/homed batch group) — a subset of `requests`
    pub batched: u64,
    /// parallel dots whose fan-out the ECM governance layer capped below
    /// the realized worker count — a subset of `parallel`
    pub capped_requests: u64,
    pub pool: PoolStats,
    /// workers whose CPU-affinity call failed (best-effort pinning signal;
    /// > 0 is a degraded-health warning in `repro engine-info`/`e2e_serve`)
    pub pin_failures: u64,
    /// workers respawned by the supervision sweep after a death or wedge
    /// (see [`WorkerPool::supervise`]) — 0 on a healthy engine
    pub respawns: u64,
    /// respawned workers whose re-pin failed — recovery succeeded but the
    /// worker runs unpinned (degraded), counted separately from first-spawn
    /// `pin_failures`
    pub respawn_pin_failures: u64,
}

/// Autotuned kernel for one request shape: the requested accuracy tier's
/// winner at the request's size class. Free functions (not methods):
/// the dispatch table is process-wide, and the sharded tier must select
/// the kernel **once** for the full request size before splitting it, so
/// every shard runs the same kernel and bit-determinism survives sharding.
pub fn kernel_for_f32(accuracy: Accuracy, total_bytes: u64) -> fn(&[f32], &[f32]) -> f32 {
    match dispatch().select(Precision::Sp, accuracy, SizeClass::of(total_bytes)).f {
        KernelFn::F32(f) => f,
        KernelFn::F64(_) => unreachable!("dispatch returned a kernel of the wrong precision"),
    }
}

pub fn kernel_for_f64(accuracy: Accuracy, total_bytes: u64) -> fn(&[f64], &[f64]) -> f64 {
    match dispatch().select(Precision::Dp, accuracy, SizeClass::of(total_bytes)).f {
        KernelFn::F64(f) => f,
        KernelFn::F32(_) => unreachable!("dispatch returned a kernel of the wrong precision"),
    }
}

/// Generates the per-precision serve methods so the size-class / cutoff /
/// admit policy lives in exactly one place.
macro_rules! engine_dot_methods {
    ($dot:ident, $dot_pooled:ident, $kernel_for:ident, $admit_local:ident,
     $parallel_capped:ident, $prec:expr, $ty:ty) => {
        /// Admit `v` into this engine's pool with the copy executed **on
        /// one of the engine's own pinned workers**, so first-touch page
        /// placement of a fresh buffer lands in the workers' NUMA domain
        /// (recycled buffers keep their prior placement, which is also
        /// in-domain once the pool has warmed up through this path).
        ///
        /// Blocks until the copy completes. Must not be called from one of
        /// this engine's own workers (the job would wait behind itself).
        pub fn $admit_local(&self, v: &[$ty]) -> Arc<PooledSlice<$ty>> {
            let (tx, rx) = std::sync::mpsc::channel();
            let pool = Arc::clone(&self.pool);
            let ptr = v.as_ptr() as usize;
            let len = v.len();
            self.workers.submit(Box::new(move || {
                // SAFETY: the caller blocks on `rx` until this job has
                // finished, so the borrow behind `ptr` outlives every use
                // of the reconstructed slice
                let src = unsafe { std::slice::from_raw_parts(ptr as *const $ty, len) };
                let _ = tx.send(Arc::new(pool.admit(src)));
            }));
            rx.recv().expect("admission worker died")
        }
        /// Serve one dot. Small dots run inline on the caller's slices
        /// (zero copy, zero dispatch — a hand-off doesn't amortize); large
        /// dots are admitted into pooled aligned buffers and chunked
        /// across the worker pool.
        ///
        /// Lengths: see the "Length policy" in [`plan`] — equal lengths
        /// are the contract (`debug_assert`ed), release builds truncate to
        /// the shorter stream.
        pub fn $dot(&self, accuracy: Accuracy, a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(
                a.len(),
                b.len(),
                "engine dot called with mismatched stream lengths (see engine length policy)"
            );
            self.requests.fetch_add(1, Ordering::Relaxed);
            let n = a.len().min(b.len());
            if n == 0 {
                // zero-length dot: exactly +0.0 in every tier, and it must
                // never cost a kernel call or a worker job (the planner's
                // predicates agree — `serves_inline(0)` is true, `splits(0)`
                // is false)
                return 0.0 as $ty;
            }
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            let f = $kernel_for(accuracy, total_bytes);
            // the Exact tier is always inline — scalar expansion arithmetic
            // has no partial-merge story (see "# Accuracy tiers" in `plan`)
            if accuracy == Accuracy::Exact || self.serves_inline(total_bytes) {
                return f(&a[..n], &b[..n]);
            }
            // worker-side admission: first-touch places fresh pool pages
            // in the workers' NUMA domain, not the caller's
            let pa = self.$admit_local(&a[..n]);
            let pb = self.$admit_local(&b[..n]);
            self.parallel_jobs.fetch_add(1, Ordering::Relaxed);
            // governance: chunk count stays the full worker count (bit
            // geometry), only the worker SUBSET that runs them may shrink
            let cap = self.worker_cap($prec, total_bytes);
            if cap < self.workers.size() {
                self.note_capped();
            }
            $parallel_capped(&self.workers, f, &pa, &pb, self.workers.size(), cap)
        }

        /// The zero-copy steady-state path: dot two already-admitted
        /// streams. Length policy as for the slice path.
        pub fn $dot_pooled(
            &self,
            accuracy: Accuracy,
            a: &Arc<PooledSlice<$ty>>,
            b: &Arc<PooledSlice<$ty>>,
        ) -> $ty {
            debug_assert_eq!(
                a.len(),
                b.len(),
                "engine dot called with mismatched stream lengths (see engine length policy)"
            );
            self.requests.fetch_add(1, Ordering::Relaxed);
            let n = a.len().min(b.len());
            if n == 0 {
                // zero-length dot: +0.0, no kernel call (see the slice path)
                return 0.0 as $ty;
            }
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            let f = $kernel_for(accuracy, total_bytes);
            if accuracy == Accuracy::Exact || self.serves_inline(total_bytes) {
                return f(&a.as_slice()[..n], &b.as_slice()[..n]);
            }
            self.parallel_jobs.fetch_add(1, Ordering::Relaxed);
            let cap = self.worker_cap($prec, total_bytes);
            if cap < self.workers.size() {
                self.note_capped();
            }
            $parallel_capped(&self.workers, f, a, b, self.workers.size(), cap)
        }
    };
}

/// Generates the per-precision batch executor: run a group of
/// inline-class requests on the CURRENT thread, sending `(index, result)`
/// per request. Maximal same-size-class runs of ≥ 2 requests go through
/// the calibrated fused multi-dot kernel (bit-identical per request to the
/// cell's single winner); everything else — shorter runs, cells whose
/// calibration kept no fused kernel, and the per-request fallback after a
/// fused-kernel panic — loops the single winner itself.
macro_rules! exec_batch_impl {
    ($name:ident, $ty:ty, $prec:expr, $kernel_for:ident, $call:ident) => {
        pub(crate) fn $name(
            accuracy: Accuracy,
            items: &[(usize, &[$ty], &[$ty])],
            tx: &std::sync::mpsc::Sender<(usize, Result<$ty, String>)>,
        ) {
            let total = |a: &[$ty]| (2 * a.len() * std::mem::size_of::<$ty>()) as u64;
            let mut i = 0usize;
            while i < items.len() {
                let class = SizeClass::of(total(items[i].1));
                let mut j = i + 1;
                while j < items.len() && SizeClass::of(total(items[j].1)) == class {
                    j += 1;
                }
                let run = &items[i..j];
                // same class ⇒ same single winner and same fused choice as
                // the serial path — the batching invariant needs exactly that
                let single = $kernel_for(accuracy, total(run[0].1));
                let mut fused_done = false;
                // fuse-or-loop is the planner's call (the calibrated
                // cutoff lives behind `plan::batch_exec`)
                if let Some(bk) = plan::batch_exec(dispatch(), $prec, accuracy, class, run.len()) {
                    let pairs: Vec<(&[$ty], &[$ty])> =
                        run.iter().map(|&(_, a, b)| (a, b)).collect();
                    let mut vals = vec![0.0 as $ty; run.len()];
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        bk.$call(&pairs, &mut vals)
                    }));
                    if r.is_ok() {
                        for (&(idx, _, _), v) in run.iter().zip(&vals) {
                            let _ = tx.send((idx, Ok(*v)));
                        }
                        FUSED_DOTS.fetch_add(run.len() as u64, Ordering::Relaxed);
                        fused_done = true;
                    }
                    // a fused-kernel panic falls through to the serial
                    // loop: only the truly panicking request errors
                }
                if !fused_done {
                    for &(idx, a, b) in run {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            single(a, b)
                        }))
                        .map_err(parallel::panic_message);
                        let _ = tx.send((idx, r));
                    }
                }
                i = j;
            }
        }
    };
}

exec_batch_impl!(exec_batch_f32, f32, Precision::Sp, kernel_for_f32, call_f32);
exec_batch_impl!(exec_batch_f64, f64, Precision::Dp, kernel_for_f64, call_f64);

/// Process-global count of dots served by a FUSED multi-dot kernel, as
/// opposed to the serial loop inside a batched execution path. Global
/// rather than per-engine because the fuse-or-loop decision runs inside
/// `exec_batch_*` on worker threads with no engine handle in scope;
/// tests observe before/after deltas to assert which tiers actually
/// fused (tiers without a fused twin — Dot2, Exact — can never move it).
static FUSED_DOTS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-global fused-dot counter.
pub fn fused_dots_total() -> u64 {
    FUSED_DOTS.load(Ordering::Relaxed)
}

/// Generates the per-precision batch methods on [`DotEngine`].
macro_rules! engine_batch_methods {
    ($dot_batch:ident, $admit_many:ident, $dot:ident, $exec:ident, $ty:ty) => {
        /// Admit several streams in ONE worker job (a single handoff and
        /// one in-domain first-touch copy pass) — the admission-coalescing
        /// primitive behind the service's `Admit` burst batching. Blocks
        /// until the copies complete; must not be called from one of this
        /// engine's own workers.
        pub fn $admit_many(&self, vs: &[&[$ty]]) -> Vec<Arc<PooledSlice<$ty>>> {
            if vs.is_empty() {
                return Vec::new();
            }
            let (tx, rx) = std::sync::mpsc::channel();
            let pool = Arc::clone(&self.pool);
            let raw: Vec<(usize, usize)> =
                vs.iter().map(|v| (v.as_ptr() as usize, v.len())).collect();
            self.workers.submit(Box::new(move || {
                // SAFETY: the caller blocks on `rx` until this job has
                // finished, so the borrows behind the raw pointers outlive
                // every reconstructed slice
                let out: Vec<Arc<PooledSlice<$ty>>> = raw
                    .iter()
                    .map(|&(p, n)| {
                        let src = unsafe { std::slice::from_raw_parts(p as *const $ty, n) };
                        Arc::new(pool.admit(src))
                    })
                    .collect();
                let _ = tx.send(out);
            }));
            rx.recv().expect("admission worker died")
        }

        /// Serve a batch of independent dots — bit-identical to calling
        /// the single-dot method once per request (the [`plan`] module's
        /// "Batching invariant"). Inline-class requests are grouped into one
        /// fused/serial kernel pass per worker-job chunk-group (or run on
        /// the calling thread when the whole batch is cheaper than a
        /// handoff); requests big enough for the chunked-parallel path
        /// take the exact serial route one by one. Must not be called
        /// from one of this engine's own workers.
        pub fn $dot_batch(&self, accuracy: Accuracy, reqs: &[(&[$ty], &[$ty])]) -> Vec<$ty> {
            let mut out = vec![0.0 as $ty; reqs.len()];
            let mut smalls: Vec<(usize, &[$ty], &[$ty])> = Vec::with_capacity(reqs.len());
            let mut bigs: Vec<usize> = Vec::new();
            let mut small_bytes = 0u64;
            for (i, &(a, b)) in reqs.iter().enumerate() {
                debug_assert_eq!(
                    a.len(),
                    b.len(),
                    "engine dot called with mismatched stream lengths (see engine length policy)"
                );
                let n = a.len().min(b.len());
                if n == 0 {
                    // zero-length dot: `out[i]` is already the answer
                    // (+0.0) — it never joins a worker chunk-group, so an
                    // empty request can't cost a handoff. Still a served
                    // request, so it counts like the single-dot path does.
                    self.requests.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let total = (2 * n * std::mem::size_of::<$ty>()) as u64;
                if accuracy == Accuracy::Exact || self.serves_inline(total) {
                    small_bytes += total;
                    smalls.push((i, &a[..n], &b[..n]));
                } else {
                    bigs.push(i);
                }
            }
            self.note_batch(smalls.len());
            let (tx, rx) = std::sync::mpsc::channel();
            if !smalls.is_empty() {
                // the planner's inline predicate again, applied to the
                // batch as a whole: if ALL the smalls together are under
                // the cutoff, even one handoff can't pay for itself
                if self.serves_inline(small_bytes) {
                    // the whole batch is cheaper than a handoff: fused
                    // execution right here, zero dispatch
                    $exec(accuracy, &smalls, &tx);
                } else {
                    // one worker job per contiguous chunk-group of requests
                    let groups = self.workers.size().min(smalls.len());
                    for g in 0..groups {
                        let lo = smalls.len() * g / groups;
                        let hi = smalls.len() * (g + 1) / groups;
                        let raw: Vec<(usize, usize, usize, usize)> = smalls[lo..hi]
                            .iter()
                            .map(|&(i, a, b)| {
                                (i, a.as_ptr() as usize, b.as_ptr() as usize, a.len())
                            })
                            .collect();
                        let tx = tx.clone();
                        self.workers.submit_to(
                            g,
                            Box::new(move || {
                                // SAFETY: the caller blocks on `rx` below
                                // until every request has reported, so the
                                // borrows behind the raw pointers outlive
                                // every reconstructed slice
                                let items: Vec<(usize, &[$ty], &[$ty])> = raw
                                    .iter()
                                    .map(|&(i, pa, pb, n)| unsafe {
                                        (
                                            i,
                                            std::slice::from_raw_parts(pa as *const $ty, n),
                                            std::slice::from_raw_parts(pb as *const $ty, n),
                                        )
                                    })
                                    .collect();
                                $exec(accuracy, &items, &tx);
                            }),
                        );
                    }
                }
            }
            drop(tx);
            // big dots take the exact serial path while the groups run
            for &i in &bigs {
                let (a, b) = reqs[i];
                out[i] = self.$dot(accuracy, a, b);
            }
            let mut got = 0usize;
            for (i, r) in rx {
                out[i] = r.unwrap_or_else(|m| {
                    panic!("{}: request {i} panicked: {m}", stringify!($dot_batch))
                });
                got += 1;
            }
            assert_eq!(
                got,
                smalls.len(),
                "{}: a batch group reported no result (worker died)",
                stringify!($dot_batch)
            );
            out
        }
    };
}

/// The persistent engine: one buffer pool + one pinned worker pool,
/// alive for the life of the process (or of an explicitly created engine).
pub struct DotEngine {
    pool: Arc<BufferPool>,
    workers: WorkerPool,
    cfg: EngineConfig,
    /// governance worker caps, `[precision][size class]` (`usize::MAX` =
    /// the class does not saturate) — the host ECM verdict when
    /// `cfg.governance`, fully open otherwise
    caps: [[usize; 3]; 2],
    requests: AtomicU64,
    parallel_jobs: AtomicU64,
    batched: AtomicU64,
    capped: AtomicU64,
}

impl DotEngine {
    pub fn new(cfg: EngineConfig) -> DotEngine {
        Self::new_on(cfg, &[])
    }

    /// Engine whose workers are pinned round-robin onto the explicit CPU
    /// list `cpus` — the per-NUMA-domain shard constructor. `cfg.threads ==
    /// 0` means one worker per listed CPU (or per online CPU when `cpus`
    /// is empty, which also falls back to default online-set pinning).
    pub fn new_on(cfg: EngineConfig, cpus: &[usize]) -> DotEngine {
        let threads = if cfg.threads != 0 {
            cfg.threads
        } else if !cpus.is_empty() {
            cpus.len()
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        let caps = if cfg.governance {
            crate::ecm::governance::host_verdict().worker_caps()
        } else {
            [[usize::MAX; 3]; 2]
        };
        DotEngine {
            pool: BufferPool::new(),
            workers: WorkerPool::new_on(threads, cpus),
            cfg,
            caps,
            requests: AtomicU64::new(0),
            parallel_jobs: AtomicU64::new(0),
            batched: AtomicU64::new(0),
            capped: AtomicU64::new(0),
        }
    }

    /// Override the governance caps (`[precision][size class]`,
    /// `usize::MAX` = uncapped) — bench saturation sweeps and property
    /// tests pin explicit caps so their capped-vs-uncapped comparisons
    /// don't depend on the host the suite happens to run on.
    pub fn set_worker_caps(&mut self, caps: [[usize; 3]; 2]) {
        self.caps = caps;
    }

    /// The realized fan-out for one parallel dot: the governance cap for
    /// the request's (precision, size class), corrected by the autotuner's
    /// observed-saturation feedback, clamped into `[1, worker count]`.
    /// With governance off (or a class that never saturates) this is
    /// exactly the worker count — the pre-governance behaviour.
    pub(crate) fn worker_cap(&self, prec: Precision, total_bytes: u64) -> usize {
        let class = SizeClass::of(total_bytes);
        let base = self.caps[autotune::prec_index(prec)][class.index()];
        dispatch().corrected_sat(prec, class, base).min(self.workers.size()).max(1)
    }

    /// Count one parallel dot whose fan-out governance capped below the
    /// realized worker count (the sharded split path reports its own).
    pub(crate) fn note_capped(&self) {
        self.capped.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether a request of `total_bytes` (both streams) runs inline on
    /// the submitting thread rather than the chunked-parallel path — THE
    /// predicate the dot methods use, shared with the batch paths so both
    /// split requests identically (anything else would break the batching
    /// invariant). The decision itself lives in the planner
    /// ([`plan::serves_inline`]); this is just the engine's view of it.
    pub(crate) fn serves_inline(&self, total_bytes: u64) -> bool {
        plan::serves_inline(total_bytes, self.cfg.parallel_cutoff_bytes, self.workers.size())
    }

    /// Count `k` requests served through a batched execution path (the
    /// sharded tier's batch groups execute on workers and bypass the
    /// per-request dot methods, so they report here).
    pub(crate) fn note_batch(&self, k: usize) {
        self.requests.fetch_add(k as u64, Ordering::Relaxed);
        self.batched.fetch_add(k as u64, Ordering::Relaxed);
    }

    /// Count one request served without any execution at all — the
    /// sharded batch layers resolve zero-length dots in place (the answer
    /// is +0.0) instead of dispatching them to a worker group.
    pub(crate) fn note_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// The shard tier schedules chunk jobs straight onto a shard's workers.
    pub(crate) fn workers(&self) -> &WorkerPool {
        &self.workers
    }

    /// The process-wide engine: the first shard of
    /// [`ShardedEngine::global`]. Delegating (rather than holding a second
    /// `OnceLock`) means a process that touches both globals gets ONE
    /// pinned worker fleet, not two fleets contending for the same CPUs.
    /// Standalone engines remain available via [`DotEngine::new`].
    pub fn global() -> &'static DotEngine {
        ShardedEngine::global().shard(0)
    }

    pub fn threads(&self) -> usize {
        self.workers.size()
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            requests: self.requests.load(Ordering::Relaxed),
            parallel: self.parallel_jobs.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            capped_requests: self.capped.load(Ordering::Relaxed),
            pool: self.pool.stats(),
            pin_failures: self.workers.pin_failures() as u64,
            respawns: self.workers.respawns() as u64,
            respawn_pin_failures: self.workers.respawn_pin_failures() as u64,
        }
    }

    /// One self-healing sweep over this engine's workers (see
    /// [`WorkerPool::supervise`]); `wedge_us == 0` disables wedge
    /// detection, dead-thread detection is always on. Returns workers
    /// respawned. Driven periodically by the service supervisor; safe to
    /// call from any thread.
    pub fn supervise(&self, wedge_us: u64) -> usize {
        self.workers.supervise(wedge_us)
    }

    /// Admit a stream into the engine's pooled aligned storage (for callers
    /// that hold inputs across many dots — the zero-copy steady state).
    pub fn admit_f32(&self, v: &[f32]) -> Arc<PooledSlice<f32>> {
        Arc::new(self.pool.admit(v))
    }

    pub fn admit_f64(&self, v: &[f64]) -> Arc<PooledSlice<f64>> {
        Arc::new(self.pool.admit(v))
    }

    engine_dot_methods!(
        dot_f32,
        dot_pooled_f32,
        kernel_for_f32,
        admit_local_f32,
        parallel_dot_capped_f32,
        Precision::Sp,
        f32
    );
    engine_dot_methods!(
        dot_f64,
        dot_pooled_f64,
        kernel_for_f64,
        admit_local_f64,
        parallel_dot_capped_f64,
        Precision::Dp,
        f64
    );
    engine_batch_methods!(dot_batch_f32, admit_local_many_f32, dot_f32, exec_batch_f32, f32);
    engine_batch_methods!(dot_batch_f64, admit_local_many_f64, dot_f64, exec_batch_f64, f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    /// One engine for the module's tests: calibration (via `dispatch()`)
    /// runs once per process.
    fn engine() -> DotEngine {
        DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() })
    }

    #[test]
    fn small_and_large_paths_agree_with_exact() {
        let e = engine();
        let mut rng = Rng::new(11);
        // n=1000 stays inline; n=200_000 (1.6 MB) takes the parallel path
        for n in [1000usize, 200_000] {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 =
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
            let got = e.dot_f32(Accuracy::Kahan, &a, &b) as f64;
            assert!((got - exact).abs() / scale < 1e-6, "n={n}");
            let gotn = e.dot_f32(Accuracy::Naive, &a, &b) as f64;
            assert!((gotn - exact).abs() / scale < 1e-4, "naive n={n}");
        }
        let s = e.stats();
        assert_eq!(s.requests, 4);
        assert_eq!(s.parallel, 2, "only the large dots may go parallel: {s:?}");
    }

    #[test]
    fn pooled_path_reuses_buffers_and_matches() {
        let e = engine();
        let mut rng = Rng::new(13);
        let n = 300_000;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);

        // request path: admit per call — buffers recycle after round 1
        let first = e.dot_f32(Accuracy::Kahan, &av, &bv);
        for _ in 0..3 {
            let again = e.dot_f32(Accuracy::Kahan, &av, &bv);
            assert_eq!(first.to_bits(), again.to_bits(), "deterministic");
        }
        assert!(e.stats().pool.hits >= 6, "{:?}", e.stats());

        // steady-state path: admit once, dot many
        let pa = e.admit_f32(&av);
        let pb = e.admit_f32(&bv);
        let v = e.dot_pooled_f32(Accuracy::Kahan, &pa, &pb) as f64;
        assert!((v - exact).abs() / scale < 1e-6);
    }

    #[test]
    fn f64_engine_path() {
        use crate::accuracy::exact::exact_dot_f64;
        let e = engine();
        let mut rng = Rng::new(17);
        let n = 150_000; // 2.4 MB total -> parallel
        let a = rng.normal_f64_vec(n);
        let b = rng.normal_f64_vec(n);
        let exact = exact_dot_f64(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1e-300);
        let got = e.dot_f64(Accuracy::Kahan, &a, &b);
        assert!((got - exact).abs() / scale < 1e-14);
        // zero-copy steady state exists for f64 too
        let pa = e.admit_f64(&a);
        let pb = e.admit_f64(&b);
        let pooled = e.dot_pooled_f64(Accuracy::Kahan, &pa, &pb);
        assert!((pooled - exact).abs() / scale < 1e-14);
    }

    #[test]
    fn global_engine_is_a_singleton() {
        let a = DotEngine::global() as *const _;
        let b = DotEngine::global() as *const _;
        assert_eq!(a, b);
    }

    /// The batching invariant at the engine layer: a mixed-size batch
    /// (inline-class smalls + one chunked-parallel big) returns exactly
    /// the bits of serial execution, and the stats split out the batched
    /// subset.
    #[test]
    fn dot_batch_bit_identical_to_serial_and_counted() {
        let e = engine();
        let mut rng = Rng::new(23);
        let sizes = [64usize, 1000, 4096, 200_000, 257, 8192];
        let reqs: Vec<(Vec<f32>, Vec<f32>)> =
            sizes.iter().map(|&n| (rng.normal_f32_vec(n), rng.normal_f32_vec(n))).collect();
        let view: Vec<(&[f32], &[f32])> =
            reqs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let serial: Vec<f32> =
            view.iter().map(|&(a, b)| e.dot_f32(Accuracy::Kahan, a, b)).collect();
        let batched = e.dot_batch_f32(Accuracy::Kahan, &view);
        for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
            assert_eq!(s.to_bits(), g.to_bits(), "req {i} (n={})", sizes[i]);
        }
        let st = e.stats();
        // serial: 6 requests; batch: 6 more, 5 of them small (200_000
        // elems = 1.6 MB takes the parallel path in both runs)
        assert_eq!(st.requests, 12, "{st:?}");
        assert_eq!(st.batched, 5, "{st:?}");
        assert_eq!(st.parallel, 2, "{st:?}");
    }

    /// The governance contract at the engine facade: an explicit cap
    /// changes concurrency only (bits identical to an ungoverned engine)
    /// and is visible in `EngineStats::capped_requests`; an ungoverned
    /// engine never counts a capped request.
    #[test]
    fn governed_cap_is_concurrency_only_and_counted() {
        let mut governed = DotEngine::new(EngineConfig {
            threads: 2,
            governance: false,
            ..EngineConfig::default()
        });
        governed.set_worker_caps([[1, 1, 1], [1, 1, 1]]);
        let open = DotEngine::new(EngineConfig {
            threads: 2,
            governance: false,
            ..EngineConfig::default()
        });
        let mut rng = Rng::new(29);
        let n = 200_000; // 1.6 MB total -> chunked-parallel path
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let x = governed.dot_f32(Accuracy::Kahan, &a, &b);
        let y = open.dot_f32(Accuracy::Kahan, &a, &b);
        assert_eq!(x.to_bits(), y.to_bits(), "a worker cap must never change bits");
        let (gs, os) = (governed.stats(), open.stats());
        assert_eq!(gs.capped_requests, 1, "{gs:?}");
        assert_eq!(gs.parallel, 1, "{gs:?}");
        assert_eq!(os.capped_requests, 0, "{os:?}");
    }

    #[test]
    fn admit_local_many_preserves_contents_in_one_pass() {
        let e = engine();
        let a: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..500).map(|i| -(i as f32)).collect();
        let admitted = e.admit_local_many_f32(&[&a, &b]);
        assert_eq!(admitted.len(), 2);
        assert_eq!(admitted[0].as_slice(), &a[..]);
        assert_eq!(admitted[1].as_slice(), &b[..]);
        assert_eq!(admitted[0].addr() % 64, 0);
        assert!(e.admit_local_many_f64(&[]).is_empty());
    }
}
