//! Persistent parallel dot engine — the allocation-free request hot path.
//!
//! The paper's headline is that a Kahan-compensated dot is (nearly) free
//! once SIMD, unrolling and bandwidth saturation are exploited. This module
//! is the serving-side consequence: keep everything that is expensive to
//! set up — aligned buffers, pinned threads, kernel selection — alive
//! across requests, so the steady-state cost of a served dot is the
//! streaming cost the paper models and nothing else.
//!
//! # Architecture: pool → partition → kernel → compensated merge
//!
//! ```text
//!                  ┌────────────────────────────────────────────────┐
//!   request(a, b)  │ DotEngine                                      │
//!   ─────────────► │  1. pool   : admit streams into recycled       │
//!                  │              64-byte-aligned buffers (zero     │
//!                  │              heap allocation at steady state)  │
//!                  │  2. partition: cut into cache-line-aligned     │
//!                  │              chunks, one per pinned worker     │
//!                  │  3. kernel : per chunk, the autotuned best     │
//!                  │              host SIMD kernel for              │
//!                  │              (precision, size class)           │
//!                  │  4. merge  : compensated (Neumaier) fold of    │
//!                  │              per-chunk partials, chunk order   │
//!                  └────────────────────────────────────────────────┘
//! ```
//!
//! * [`pool`] — the recycling aligned buffer pool ([`BufferPool`]).
//! * [`parallel`] — the long-lived pinned worker pool ([`WorkerPool`]) and
//!   the chunked compensated reduction (`parallel_dot_*`).
//! * [`autotune`] — first-use micro-calibration of the kernel registry into
//!   a `(Precision, SizeClass)` dispatch table behind a `OnceLock`.
//!
//! # Accuracy
//!
//! Each chunk is a full Kahan dot (per-lane compensation folded by the
//! kernel); the cross-chunk merge reuses the registry's compensated fold.
//! The parallel result therefore keeps the sequential Kahan error bound
//! `O(u)·Σ|aᵢbᵢ|` for any chunk count — see the property tests in
//! `rust/tests/test_engine.rs` (random lengths, chunk counts, and
//! Ogita–Rump–Oishi ill-conditioned inputs).
//!
//! # Determinism
//!
//! Chunk boundaries depend only on `(n, worker count)` and partials merge
//! in chunk order, so results are bit-reproducible run to run for a fixed
//! engine configuration.
//!
//! # Who uses it
//!
//! * `coordinator::service` executes host-backend requests here (the
//!   default backend; PJRT remains available behind `Backend::Pjrt`).
//! * `bench::threads::scaling_curve` reuses one [`WorkerPool`] across all
//!   thread counts instead of re-spawning per measurement.
//! * `benches/bench_engine.rs` records the engine-vs-spawn-per-call
//!   speedup into `BENCH_engine.json`.

pub mod autotune;
pub mod parallel;
pub mod pool;

pub use autotune::{dispatch, Choice, DispatchTable, SizeClass};
pub use parallel::{chunk_ranges, parallel_dot_f32, parallel_dot_f64, WorkerPool};
pub use pool::{BufferPool, PoolStats, PooledSlice};

use crate::bench::kernels::KernelFn;
use crate::isa::{Precision, Variant};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// worker threads; 0 = one per online CPU
    pub threads: usize,
    /// total working sets (both streams, bytes) below this run on the
    /// caller's thread directly over the caller's slices (zero copy, zero
    /// dispatch) — small dots don't amortize a hand-off
    pub parallel_cutoff_bytes: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: 0, parallel_cutoff_bytes: 256 * 1024 }
    }
}

/// Aggregate engine counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// dots served
    pub requests: u64,
    /// dots that took the chunked-parallel path
    pub parallel: u64,
    pub pool: PoolStats,
}

/// Generates the per-precision serve methods so the size-class / cutoff /
/// admit policy lives in exactly one place.
macro_rules! engine_dot_methods {
    ($dot:ident, $dot_pooled:ident, $select:ident, $admit:ident,
     $parallel:ident, $arm:ident, $ty:ty, $prec:expr) => {
        fn $select(&self, variant: Variant, total_bytes: u64) -> fn(&[$ty], &[$ty]) -> $ty {
            let class = SizeClass::of(total_bytes);
            match dispatch().select($prec, variant, class).f {
                KernelFn::$arm(f) => f,
                _ => unreachable!("dispatch returned a kernel of the wrong precision"),
            }
        }

        /// Serve one dot. Small dots run inline on the caller's slices
        /// (zero copy, zero dispatch — a hand-off doesn't amortize); large
        /// dots are admitted into pooled aligned buffers and chunked
        /// across the worker pool.
        pub fn $dot(&self, variant: Variant, a: &[$ty], b: &[$ty]) -> $ty {
            self.requests.fetch_add(1, Ordering::Relaxed);
            let n = a.len().min(b.len());
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            let f = self.$select(variant, total_bytes);
            if total_bytes < self.cfg.parallel_cutoff_bytes as u64 || self.workers.size() == 1 {
                return f(&a[..n], &b[..n]);
            }
            let pa = self.$admit(&a[..n]);
            let pb = self.$admit(&b[..n]);
            self.parallel_jobs.fetch_add(1, Ordering::Relaxed);
            $parallel(&self.workers, f, &pa, &pb, self.workers.size())
        }

        /// The zero-copy steady-state path: dot two already-admitted
        /// streams.
        pub fn $dot_pooled(
            &self,
            variant: Variant,
            a: &Arc<PooledSlice<$ty>>,
            b: &Arc<PooledSlice<$ty>>,
        ) -> $ty {
            self.requests.fetch_add(1, Ordering::Relaxed);
            let n = a.len().min(b.len());
            let total_bytes = (2 * n * std::mem::size_of::<$ty>()) as u64;
            let f = self.$select(variant, total_bytes);
            if total_bytes < self.cfg.parallel_cutoff_bytes as u64 || self.workers.size() == 1 {
                return f(&a.as_slice()[..n], &b.as_slice()[..n]);
            }
            self.parallel_jobs.fetch_add(1, Ordering::Relaxed);
            $parallel(&self.workers, f, a, b, self.workers.size())
        }
    };
}

/// The persistent engine: one buffer pool + one pinned worker pool,
/// alive for the life of the process (or of an explicitly created engine).
pub struct DotEngine {
    pool: Arc<BufferPool>,
    workers: WorkerPool,
    cfg: EngineConfig,
    requests: AtomicU64,
    parallel_jobs: AtomicU64,
}

impl DotEngine {
    pub fn new(cfg: EngineConfig) -> DotEngine {
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        DotEngine {
            pool: BufferPool::new(),
            workers: WorkerPool::new(threads),
            cfg,
            requests: AtomicU64::new(0),
            parallel_jobs: AtomicU64::new(0),
        }
    }

    /// The process-wide engine (used by the service's host backend).
    pub fn global() -> &'static DotEngine {
        static ENGINE: OnceLock<DotEngine> = OnceLock::new();
        ENGINE.get_or_init(|| DotEngine::new(EngineConfig::default()))
    }

    pub fn threads(&self) -> usize {
        self.workers.size()
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            requests: self.requests.load(Ordering::Relaxed),
            parallel: self.parallel_jobs.load(Ordering::Relaxed),
            pool: self.pool.stats(),
        }
    }

    /// Admit a stream into the engine's pooled aligned storage (for callers
    /// that hold inputs across many dots — the zero-copy steady state).
    pub fn admit_f32(&self, v: &[f32]) -> Arc<PooledSlice<f32>> {
        Arc::new(self.pool.admit(v))
    }

    pub fn admit_f64(&self, v: &[f64]) -> Arc<PooledSlice<f64>> {
        Arc::new(self.pool.admit(v))
    }

    engine_dot_methods!(
        dot_f32,
        dot_pooled_f32,
        select_f32,
        admit_f32,
        parallel_dot_f32,
        F32,
        f32,
        Precision::Sp
    );
    engine_dot_methods!(
        dot_f64,
        dot_pooled_f64,
        select_f64,
        admit_f64,
        parallel_dot_f64,
        F64,
        f64,
        Precision::Dp
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    /// One engine for the module's tests: calibration (via `dispatch()`)
    /// runs once per process.
    fn engine() -> DotEngine {
        DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() })
    }

    #[test]
    fn small_and_large_paths_agree_with_exact() {
        let e = engine();
        let mut rng = Rng::new(11);
        // n=1000 stays inline; n=200_000 (1.6 MB) takes the parallel path
        for n in [1000usize, 200_000] {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 =
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
            let got = e.dot_f32(Variant::Kahan, &a, &b) as f64;
            assert!((got - exact).abs() / scale < 1e-6, "n={n}");
            let gotn = e.dot_f32(Variant::Naive, &a, &b) as f64;
            assert!((gotn - exact).abs() / scale < 1e-4, "naive n={n}");
        }
        let s = e.stats();
        assert_eq!(s.requests, 4);
        assert_eq!(s.parallel, 2, "only the large dots may go parallel: {s:?}");
    }

    #[test]
    fn pooled_path_reuses_buffers_and_matches() {
        let e = engine();
        let mut rng = Rng::new(13);
        let n = 300_000;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);

        // request path: admit per call — buffers recycle after round 1
        let first = e.dot_f32(Variant::Kahan, &av, &bv);
        for _ in 0..3 {
            let again = e.dot_f32(Variant::Kahan, &av, &bv);
            assert_eq!(first.to_bits(), again.to_bits(), "deterministic");
        }
        assert!(e.stats().pool.hits >= 6, "{:?}", e.stats());

        // steady-state path: admit once, dot many
        let pa = e.admit_f32(&av);
        let pb = e.admit_f32(&bv);
        let v = e.dot_pooled_f32(Variant::Kahan, &pa, &pb) as f64;
        assert!((v - exact).abs() / scale < 1e-6);
    }

    #[test]
    fn f64_engine_path() {
        use crate::accuracy::exact::exact_dot_f64;
        let e = engine();
        let mut rng = Rng::new(17);
        let n = 150_000; // 2.4 MB total -> parallel
        let a = rng.normal_f64_vec(n);
        let b = rng.normal_f64_vec(n);
        let exact = exact_dot_f64(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1e-300);
        let got = e.dot_f64(Variant::Kahan, &a, &b);
        assert!((got - exact).abs() / scale < 1e-14);
        // zero-copy steady state exists for f64 too
        let pa = e.admit_f64(&a);
        let pb = e.admit_f64(&b);
        let pooled = e.dot_pooled_f64(Variant::Kahan, &pa, &pb);
        assert!((pooled - exact).abs() / scale < 1e-14);
    }

    #[test]
    fn global_engine_is_a_singleton() {
        let a = DotEngine::global() as *const _;
        let b = DotEngine::global() as *const _;
        assert_eq!(a, b);
    }
}
