//! The Execution–Cache–Memory (ECM) analytic performance model
//! (Treibig & Hager [9], Hager et al. [10], Stengel et al. [11]), as used by
//! the paper to predict single-core cycles per work unit in every memory
//! level and the multicore saturation point.
//!
//! Inputs are a `crate::machine::Machine` (Table 1) and a
//! `crate::isa::KernelDesc` (the generated instruction stream), so the model
//! is *derived from the kernel*, never hand-entered.

pub mod governance;
pub mod model;
pub mod notation;
pub mod scaling;

pub use governance::{host_verdict, verdict_for, EcmVerdict, ModelSource};
pub use model::{build, EcmModel};
pub use scaling::{scale_performance, ScalingCurve};
