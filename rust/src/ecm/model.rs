//! Core ECM computation: in-core times (T_OL, T_nOL) from port scheduling of
//! the kernel's instruction stream, transfer times from the machine's bus
//! widths and bandwidths, and the overlap rule of Eq. (1).

use crate::isa::{KernelDesc, Op, Variant};
use crate::machine::Machine;

/// ECM model terms for one kernel on one machine, all in cycles per **unit
/// of work** (one cache line per stream; 16 SP / 8 DP iterations).
#[derive(Clone, Debug)]
pub struct EcmModel {
    /// core cycles that overlap with transfers (FP work)
    pub t_ol: f64,
    /// core cycles that do NOT overlap (load/store retirement)
    pub t_nol: f64,
    /// transfer cycles L2→L1 per unit
    pub t_l1l2: f64,
    /// transfer cycles L3→L2 per unit
    pub t_l2l3: f64,
    /// memory→L3 transfer cycles per unit at load-only bandwidth
    pub t_l3mem_bw: f64,
    /// the paper's empirical latency penalty per unit
    pub t_l3mem_penalty: f64,
    /// iterations per unit (for performance conversion)
    pub iters_per_unit: f64,
    /// machine clock in GHz
    pub clock_ghz: f64,
    /// bytes of input consumed per iteration (intensity denominator)
    pub bytes_per_iter: f64,
    /// memory load bandwidth GB/s (roofline numerator)
    pub load_bw_gbs: f64,
}

/// Memory-hierarchy levels for predictions (index into `predictions()`).
pub const LEVELS: [&str; 4] = ["L1", "L2", "L3", "Mem"];

/// In-core FP time from port throughput plus the loop-carried chain bound.
fn t_ol(machine: &Machine, k: &KernelDesc) -> f64 {
    let units = k.units_per_stream_pass as f64;
    let mut adds = 0.0;
    let mut muls = 0.0;
    let mut fmas = 0.0;
    for i in &k.insts {
        match i.op {
            Op::Add => adds += 1.0,
            Op::Mul => muls += 1.0,
            Op::Fma => fmas += 1.0,
            _ => {}
        }
    }
    adds /= units;
    muls /= units;
    fmas /= units;

    let c = &machine.core;
    let mut t = 0.0f64;
    if adds > 0.0 {
        t = t.max(adds / c.add_ports as f64);
    }
    if c.fma_ports > 0 {
        // MULs and FMAs share the FMA pipes; stand-alone ADDs are restricted
        // to the (single) ADD-capable pipe but also occupy FMA-pipe slots
        t = t.max((adds + muls + fmas) / c.fma_ports as f64);
    } else {
        if muls > 0.0 {
            t = t.max(muls / c.mul_ports as f64);
        }
        if fmas > 0.0 {
            // no FMA hardware: treat as mul-pipe ops (compat fallback)
            t = t.max(fmas / c.mul_ports as f64);
        }
    }

    // loop-carried dependency bound: each accumulator slot's chain advances
    // one vector iteration per chain_ops * latency cycles
    let lanes = k.simd.lanes(k.elem_bytes) as f64;
    let vec_per_unit = k.iters_per_unit as f64 / lanes;
    let lat = match k.variant {
        Variant::KahanFma => c.fma_latency,
        _ => c.add_latency,
    } as f64;
    let t_chain = vec_per_unit * k.carried_chain_ops as f64 * lat / k.slots as f64;

    t.max(t_chain)
}

/// Non-overlapping core time: cycles the load/store ports are busy.
fn t_nol(machine: &Machine, k: &KernelDesc) -> f64 {
    let units = k.units_per_stream_pass as f64;
    let c = &machine.core;
    let mut load_slots = 0.0;
    let mut store_slots = 0.0;
    for i in &k.insts {
        match i.op {
            Op::Load => load_slots += c.slots(crate::machine::Unit::Load, i.width_bytes),
            Op::Store => store_slots += c.slots(crate::machine::Unit::Store, i.width_bytes),
            _ => {}
        }
    }
    let t_load = load_slots / units / c.load_ports as f64;
    let t_store = store_slots / units / c.store_ports as f64;
    t_load.max(t_store)
}

/// Build the ECM model for `kernel` on `machine`.
///
/// `single_core` selects the Uncore clock behaviour (paper: HSW stretches
/// T_L2L3 to 5.54 cy when only one core is active).
pub fn build(machine: &Machine, kernel: &KernelDesc, single_core: bool) -> EcmModel {
    // transfers count reads plus write-backs (axpy-style kernels move the
    // written stream's line both ways across every boundary)
    let cls = kernel.cl_transfers_per_unit() as f64;
    EcmModel {
        t_ol: t_ol(machine, kernel),
        t_nol: t_nol(machine, kernel),
        t_l1l2: cls * machine.t_cache_per_cl(1, single_core),
        t_l2l3: cls * machine.t_cache_per_cl(2, single_core),
        t_l3mem_bw: cls * machine.t_l3mem_per_cl(),
        t_l3mem_penalty: cls * machine.memory.latency_penalty_cy_per_cl,
        iters_per_unit: kernel.iters_per_unit as f64,
        clock_ghz: machine.clock_ghz,
        bytes_per_iter: kernel.traffic_bytes_per_iter() as f64,
        load_bw_gbs: machine.memory.load_bw_gbs,
    }
}

impl EcmModel {
    /// Data-transfer terms in level order (L2→L1, L3→L2, Mem→L3 incl.
    /// penalty).
    fn transfer_terms(&self) -> [f64; 3] {
        [self.t_l1l2, self.t_l2l3, self.t_l3mem_bw + self.t_l3mem_penalty]
    }

    /// Eq. (1): T_ECM for data resident in `level` (0 = L1 .. 3 = Mem).
    pub fn prediction(&self, level: usize) -> f64 {
        let t_data: f64 = self.transfer_terms().iter().take(level).sum();
        (self.t_nol + t_data).max(self.t_ol)
    }

    /// Cycle predictions for all four residence levels.
    pub fn predictions(&self) -> [f64; 4] {
        [self.prediction(0), self.prediction(1), self.prediction(2), self.prediction(3)]
    }

    /// Convert a cycle prediction to GUP/s ("updates" = iterations, the
    /// paper's unit of work; Eq. (2)).
    pub fn perf_gups(&self, level: usize) -> f64 {
        self.iters_per_unit * self.clock_ghz / self.prediction(level)
    }

    pub fn perf_all(&self) -> [f64; 4] {
        [self.perf_gups(0), self.perf_gups(1), self.perf_gups(2), self.perf_gups(3)]
    }

    /// Roofline memory-bandwidth light speed in GUP/s:
    /// P_BW = (1 update / bytes_per_iter) * b_S.
    pub fn roofline_gups(&self) -> f64 {
        self.load_bw_gbs / self.bytes_per_iter
    }

    /// Saturation point n_S = ceil(T_ECM^mem / T_L3Mem), where the divisor
    /// uses the *bandwidth-only* term (paper §2: "the maximum memory
    /// bandwidth has to be taken into account for the saturation point").
    pub fn saturation_cores(&self) -> u32 {
        (self.prediction(3) / self.t_l3mem_bw).ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{generate, Precision, Simd, Variant};
    use crate::machine::presets::*;

    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    /// §3: naive AVX on IVB = {2 || 4 | 4 | 4 | 6.1 + 2.9} cy,
    /// prediction {4 | 8 | 12 | 18.1 + 2.9} cy, perf {8.80|4.40|2.93|1.68}.
    #[test]
    fn naive_avx_ivb_matches_paper() {
        let m = ivb();
        let k = generate(Variant::Naive, Simd::Avx, Precision::Sp, 0);
        let e = build(&m, &k, true);
        assert_eq!(e.t_ol, 2.0);
        assert_eq!(e.t_nol, 4.0);
        assert_eq!(e.t_l1l2, 4.0);
        assert_eq!(e.t_l2l3, 4.0);
        assert!(approx(e.t_l3mem_bw, 6.1, 0.05), "{}", e.t_l3mem_bw);
        assert!(approx(e.t_l3mem_penalty, 2.9, 0.01));
        let p = e.predictions();
        assert_eq!(p[0], 4.0);
        assert_eq!(p[1], 8.0);
        assert_eq!(p[2], 12.0);
        assert!(approx(p[3], 21.0, 0.1));
        let perf = e.perf_all();
        assert!(approx(perf[0], 8.80, 0.01));
        assert!(approx(perf[1], 4.40, 0.01));
        assert!(approx(perf[2], 2.93, 0.01));
        assert!(approx(perf[3], 1.68, 0.01));
        assert_eq!(e.saturation_cores(), 4);
        assert!(approx(e.roofline_gups(), 5.76, 0.01));
    }

    /// §3 scalar Kahan on IVB: {64 || 16 | 4 | 4 | 6.1 + 2.9} cy,
    /// prediction flat 64 cy, P = 0.55 GUP/s, n_S = 11.
    #[test]
    fn kahan_scalar_ivb_matches_paper() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0);
        let e = build(&m, &k, true);
        assert_eq!(e.t_ol, 64.0);
        assert_eq!(e.t_nol, 16.0);
        assert_eq!(e.predictions(), [64.0, 64.0, 64.0, 64.0]);
        assert!(approx(e.perf_gups(3), 0.55, 0.01));
        assert_eq!(e.saturation_cores(), 11);
    }

    /// §3 SSE Kahan on IVB: {16 || 4 | 4 | 4 | 6.1+2.9}, pred {16|16|16|21}.
    #[test]
    fn kahan_sse_ivb_matches_paper() {
        let e = build(&ivb(), &generate(Variant::Kahan, Simd::Sse, Precision::Sp, 0), true);
        assert_eq!(e.t_ol, 16.0);
        assert_eq!(e.t_nol, 4.0);
        let p = e.predictions();
        assert_eq!(p[0], 16.0);
        assert_eq!(p[1], 16.0);
        assert_eq!(p[2], 16.0);
        assert!(approx(p[3], 21.0, 0.1));
        assert!(approx(e.perf_gups(0), 2.20, 0.01));
    }

    /// Table 2, row by row: AVX Kahan on all four machines.
    #[test]
    fn table2_avx_kahan_all_machines() {
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);

        // SNB {8 || 4 | 4 | 4 | 7.9 + 5.1} -> {8 | 8 | 12 | 19.9 + 5.1}
        let e = build(&snb(), &k, true);
        assert_eq!(e.t_ol, 8.0);
        assert_eq!(e.t_nol, 4.0);
        assert!(approx(e.t_l3mem_bw, 7.9, 0.05));
        assert!(approx(e.t_l3mem_penalty, 5.1, 0.01));
        assert!(approx(e.prediction(3), 25.0, 0.1));
        let p = e.perf_all();
        for (got, want) in p.iter().zip([5.40, 5.40, 3.60, 1.73]) {
            assert!(approx(*got, want, 0.01), "SNB {got} vs {want}");
        }

        // IVB {8 || 4 | 4 | 4 | 6.1 + 2.9} -> perf {4.40|4.40|2.93|1.68}
        let e = build(&ivb(), &k, true);
        for (got, want) in e.perf_all().iter().zip([4.40, 4.40, 2.93, 1.68]) {
            assert!(approx(*got, want, 0.01), "IVB {got} vs {want}");
        }
        assert_eq!(e.saturation_cores(), 4);

        // HSW {8 || 2 | 2 | 5.54 | 4.9 + 11.1} -> {8 | 8 | 9.54 | 14.44+11.1}
        let e = build(&hsw(), &k, true);
        assert_eq!(e.t_ol, 8.0);
        assert_eq!(e.t_nol, 2.0);
        assert_eq!(e.t_l1l2, 2.0);
        assert!(approx(e.t_l2l3, 5.54, 0.01));
        assert!(approx(e.t_l3mem_bw, 4.86, 0.05));
        assert!(approx(e.t_l3mem_penalty, 11.1, 0.01));
        assert!(approx(e.prediction(2), 9.54, 0.01));
        assert!(approx(e.prediction(3), 25.54, 0.15));
        for (got, want) in e.perf_all().iter().zip([4.60, 4.60, 3.86, 1.44]) {
            assert!(approx(*got, want, 0.01), "HSW {got} vs {want}");
        }

        // BDW {8 || 2 | 2 | 4 | 7 + 1} -> {8 | 8 | 8 | 15 + 1}
        let e = build(&bdw(), &k, false);
        assert_eq!(e.t_ol, 8.0);
        assert_eq!(e.t_nol, 2.0);
        assert_eq!(e.t_l2l3, 4.0);
        assert!(approx(e.t_l3mem_bw, 6.98, 0.05));
        assert!(approx(e.t_l3mem_penalty, 1.0, 0.01));
        assert!(approx(e.prediction(3), 16.0, 0.1));
        for (got, want) in e.perf_all().iter().zip([3.60, 3.60, 3.60, 1.80]) {
            assert!(approx(*got, want, 0.01), "BDW {got} vs {want}");
        }
    }

    /// §3 "Double vs single precision": DP scalar Kahan on IVB is
    /// {32 || 8 | 4 | 4 | 6.1 + 2.9} -> flat 32 cy, n_S = 6,
    /// roofline 2.88 GUP/s.
    #[test]
    fn dp_scalar_kahan_ivb() {
        let e = build(&ivb(), &generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0), true);
        assert_eq!(e.t_ol, 32.0);
        assert_eq!(e.t_nol, 8.0);
        assert_eq!(e.predictions(), [32.0, 32.0, 32.0, 32.0]);
        assert!(approx(e.perf_gups(3), 0.55, 0.01));
        assert_eq!(e.saturation_cores(), 6);
        assert!(approx(e.roofline_gups(), 2.88, 0.01));
    }

    /// §4 FMA discussion: ~20% L1 speedup on HSW/BDW, nothing beyond L1.
    #[test]
    fn fma_variant_hsw_l1_speedup() {
        let m = hsw();
        let add = build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), true);
        let fma = build(&m, &generate(Variant::KahanFma, Simd::Avx, Precision::Sp, 0), true);
        let speedup = add.prediction(0) / fma.prediction(0);
        assert!(
            (1.15..=1.25).contains(&speedup),
            "L1 FMA speedup {speedup}, paper says ~20%"
        );
        // beyond L1: no noticeable improvement (memory prediction within 5%)
        let mem_ratio = add.prediction(3) / fma.prediction(3);
        assert!((0.95..=1.05).contains(&mem_ratio), "{mem_ratio}");
    }

    /// AVX vs SSE on IVB: 2x in L1/L2, ~1.3x in L3, ~1x in memory (§3).
    #[test]
    fn avx_over_sse_speedups_ivb() {
        let m = ivb();
        let sse = build(&m, &generate(Variant::Kahan, Simd::Sse, Precision::Sp, 0), true);
        let avx = build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), true);
        assert!(approx(sse.prediction(0) / avx.prediction(0), 2.0, 0.01));
        assert!(approx(sse.prediction(1) / avx.prediction(1), 2.0, 0.01));
        let l3 = sse.prediction(2) / avx.prediction(2);
        assert!((1.25..=1.40).contains(&l3), "L3 speedup {l3}");
        assert!(approx(sse.prediction(3) / avx.prediction(3), 1.0, 0.01));
    }
}
