//! The paper's shorthand notation for ECM models and predictions:
//!
//! * model:      `{ T_OL || T_nOL | T_L1L2 | T_L2L3 | T_L3Mem(+pen) } cy`
//! * prediction: `{ T_L1 | T_L2 | T_L3 | T_Mem(+pen) } cy`
//! * performance:`{ P_L1 | P_L2 | P_L3 | P_Mem } GUP/s`
//!
//! A parser is provided so tests can round-trip the strings and so the
//! validation harness can compare against paper-quoted literals.

use super::model::EcmModel;
use crate::util::fmt;

/// Format the full model, e.g. `{8 || 4 | 4 | 4 | 6.1 + 2.9}`.
pub fn format_model(e: &EcmModel) -> String {
    format!(
        "{{{} || {} | {} | {} | {} + {}}}",
        fmt::cy(e.t_ol),
        fmt::cy(e.t_nol),
        fmt::cy(e.t_l1l2),
        fmt::cy(e.t_l2l3),
        fmt::cy(e.t_l3mem_bw),
        fmt::cy(e.t_l3mem_penalty)
    )
}

/// Format the cycle predictions, e.g. `{8 | 8 | 12 | 18.1 + 2.9}`.
/// The memory entry is shown split into bandwidth + penalty parts, exactly
/// like Table 2.
pub fn format_prediction(e: &EcmModel) -> String {
    let p = e.predictions();
    let mem_bw_part = p[3] - e.t_l3mem_penalty;
    format!(
        "{{{} | {} | {} | {} + {}}}",
        fmt::cy(p[0]),
        fmt::cy(p[1]),
        fmt::cy(p[2]),
        fmt::cy(mem_bw_part),
        fmt::cy(e.t_l3mem_penalty)
    )
}

/// Format the performance prediction, e.g. `{4.40 | 4.40 | 2.93 | 1.68}`.
pub fn format_perf(e: &EcmModel) -> String {
    let p = e.perf_all();
    format!(
        "{{{} | {} | {} | {}}}",
        fmt::perf(p[0]),
        fmt::perf(p[1]),
        fmt::perf(p[2]),
        fmt::perf(p[3])
    )
}

/// Parse a shorthand like `{8 || 4 | 4 | 4 | 6.1 + 2.9}` into its numeric
/// fields: returns (t_ol if present, remaining terms with `a + b` summed).
pub fn parse_shorthand(s: &str) -> Result<(Option<f64>, Vec<f64>), String> {
    let inner = s
        .trim()
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .ok_or_else(|| format!("missing braces: `{s}`"))?;

    let (t_ol, rest) = match inner.split_once("||") {
        Some((ol, rest)) => {
            let v = parse_term(ol)?;
            (Some(v), rest)
        }
        None => (None, inner),
    };

    let terms = rest
        .split('|')
        .map(parse_term)
        .collect::<Result<Vec<_>, _>>()?;
    Ok((t_ol, terms))
}

fn parse_term(t: &str) -> Result<f64, String> {
    let t = t.trim();
    let mut sum = 0.0;
    for part in t.split('+') {
        sum += part
            .trim()
            .parse::<f64>()
            .map_err(|_| format!("bad number `{part}` in `{t}`"))?;
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecm::build;
    use crate::isa::{generate, Precision, Simd, Variant};
    use crate::machine::presets::ivb;

    #[test]
    fn ivb_kahan_avx_strings_match_paper() {
        let e = build(&ivb(), &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), true);
        // the paper prints the memory term as "6.1"; we keep two decimals
        // (6.109 cy -> "6.11"), everything else matches verbatim
        assert_eq!(format_model(&e), "{8 || 4 | 4 | 4 | 6.11 + 2.9}");
        assert_eq!(format_prediction(&e), "{8 | 8 | 12 | 18.11 + 2.9}");
        assert_eq!(format_perf(&e), "{4.40 | 4.40 | 2.93 | 1.68}");
    }

    #[test]
    fn parse_model_roundtrip() {
        let (t_ol, terms) = parse_shorthand("{8 || 4 | 4 | 4 | 6.1 + 2.9}").unwrap();
        assert_eq!(t_ol, Some(8.0));
        assert_eq!(terms, vec![4.0, 4.0, 4.0, 9.0]);
    }

    #[test]
    fn parse_prediction_no_overlap_marker() {
        let (t_ol, terms) = parse_shorthand("{4 | 8 | 12 | 21}").unwrap();
        assert_eq!(t_ol, None);
        assert_eq!(terms, vec![4.0, 8.0, 12.0, 21.0]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_shorthand("8 || 4").is_err());
        assert!(parse_shorthand("{a || 4 | 2}").is_err());
    }
}
