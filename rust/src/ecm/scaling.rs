//! Multicore scaling in the ECM framework (paper §2, end):
//! P(n) = min(n * P_ECM^mem, I * b_S), saturating at
//! n_S = ceil(T_ECM^mem / T_L3Mem).

use super::model::EcmModel;

/// One point of the scaling curve.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    pub cores: u32,
    /// predicted performance, GUP/s
    pub gups: f64,
    /// whether the bandwidth ceiling is the binding constraint
    pub bandwidth_bound: bool,
}

/// A full scaling curve for one kernel on one machine.
#[derive(Clone, Debug)]
pub struct ScalingCurve {
    pub points: Vec<ScalingPoint>,
    pub roofline_gups: f64,
    pub saturation_cores: u32,
}

/// Predicted multicore performance at `n` cores for in-memory working sets.
///
/// Paper §2 (end): P(n) = min(n · P_ECM^mem, I · b_S) — linear single-core
/// scaling clipped at the roofline bandwidth light speed, where
/// P_ECM^mem = `EcmModel::perf_gups(3)` (the Eq. (1)/(2) in-memory
/// prediction) and I · b_S = `EcmModel::roofline_gups()`.
///
/// Uses the *multi-core* ECM model (`single_core = false` Uncore behaviour
/// should be baked into `e` by the caller when modeling n > 1).
pub fn scale_performance(e: &EcmModel, n: u32) -> f64 {
    let per_core = e.perf_gups(3);
    (n as f64 * per_core).min(e.roofline_gups())
}

/// Build the scaling curve for 1..=max_cores.
///
/// Each point is paper §2's P(n) = min(n · P_ECM^mem, I · b_S) (the same
/// formula as [`scale_performance`], kept inline so the roofline is
/// evaluated once), and the curve's saturation point is the paper's
/// n_S = ceil(T_ECM^mem / T_L3Mem) via [`EcmModel::saturation_cores`] —
/// the model's single home for that equation.
pub fn curve(e: &EcmModel, max_cores: u32) -> ScalingCurve {
    let roof = e.roofline_gups();
    let points = (1..=max_cores)
        .map(|n| {
            let linear = n as f64 * e.perf_gups(3);
            ScalingPoint {
                cores: n,
                gups: linear.min(roof),
                bandwidth_bound: linear >= roof,
            }
        })
        .collect();
    ScalingCurve { points, roofline_gups: roof, saturation_cores: e.saturation_cores() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecm::build;
    use crate::isa::{generate, Precision, Simd, Variant};
    use crate::machine::presets::ivb;

    /// Fig. 3a: on IVB (SP), AVX/SSE Kahan saturate at ~4 cores at the
    /// roofline (5.76 GUP/s); scalar Kahan cannot saturate with 10 cores.
    #[test]
    fn fig3a_scaling_shapes() {
        let m = ivb();
        let avx = build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), false);
        let c = curve(&avx, m.cores);
        assert_eq!(c.saturation_cores, 4);
        assert!((c.points[9].gups - 5.76).abs() < 0.01, "saturated at roofline");
        assert!(c.points[9].bandwidth_bound);
        assert!(!c.points[0].bandwidth_bound);

        let scalar = build(&m, &generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0), false);
        let c = curve(&scalar, m.cores);
        assert_eq!(c.saturation_cores, 11); // > 10 physical cores
        assert!(!c.points[9].bandwidth_bound, "scalar must not saturate");
        assert!((c.points[9].gups - 5.5).abs() < 0.1); // 10 * 0.55
    }

    /// Fig. 3b: DP scalar saturates at ~6 cores.
    #[test]
    fn fig3b_dp_scalar_saturates() {
        let m = ivb();
        let e = build(&m, &generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0), false);
        let c = curve(&e, m.cores);
        assert_eq!(c.saturation_cores, 6);
        assert!(c.points[6].bandwidth_bound);
        assert!((c.roofline_gups - 2.88).abs() < 0.01);
    }

    #[test]
    fn monotone_nondecreasing() {
        let m = ivb();
        let e = build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), false);
        let c = curve(&e, m.cores);
        for w in c.points.windows(2) {
            assert!(w[1].gups >= w[0].gups - 1e-12);
        }
    }
}
