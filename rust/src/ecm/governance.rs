//! ECM-guided worker governance: bridge the analytic model onto the
//! silicon the process is actually running on and turn its saturation
//! prediction into per-(precision, size-class) worker caps for the
//! execution tier.
//!
//! The paper's core multicore result (§2, end) is that a memory-bound dot
//! stops scaling at n_S = ceil(T_ECM^mem / T_L3Mem) cores — every worker
//! past saturation adds nothing but contention. This module computes that
//! bound for the *detected* host (the saturation point shifts per
//! generation, so a constant would be wrong on most machines):
//!
//! * [`bridge_host`] builds a governable [`Machine`] from
//!   `machine::detect` plus the measured streaming load bandwidth
//!   (replacing the detector's placeholder figure), falling back to the
//!   nearest Table-1 preset when the calibration looks implausible
//!   (virtualized TSC, throttled runners).
//! * [`verdict_for`] evaluates the Kahan ECM model per precision and maps
//!   saturation onto size classes: only the MEM class has a shared-
//!   bandwidth ceiling — L1/L2 are core-private and the segmented L3
//!   scales with active cores (paper §2/§3), so L1- and LLC-class dots
//!   never cap.
//! * [`host_verdict`] caches the whole thing per process (the bandwidth
//!   measurement streams ~64 MiB).
//!
//! Consumers: `engine::plan::PlanPolicy::with_governance` carries the caps
//! into routing, the engine/sharded execution paths realize them as worker
//! *subsets* (concurrency only — never chunk geometry, so capped and
//! uncapped execution are bit-identical), and `repro plan` /
//! `repro engine-info` print the verdict.
//!
//! Persistence: the verdict itself is re-derived every start (cheap, and
//! it must track the running host), but the *empirical corrections* the
//! bench sweep feeds back (`DispatchTable::note_saturation`) are carried
//! across runs by `engine::profile` — a `repro calibrate --write` run
//! records the per-(precision, size-class) correction factors, and a
//! loaded profile seeds them back into the dispatch table at startup, so
//! a mispredicting model is corrected from the first request, not from
//! the first completed sweep.

use super::model::{build, EcmModel};
use crate::isa::{generate, Precision, Simd, Variant};
use crate::machine::detect::{calibrate_tsc_ghz_cached, detect_host_cached, host_simd};
use crate::machine::{nearest_preset, preset, Machine, PresetId};
use std::sync::OnceLock;

/// Index conventions shared with `engine::autotune`: precision 0 = SP,
/// 1 = DP; size class 0 = L1, 1 = LLC, 2 = MEM.
pub const PREC_NAMES: [&str; 2] = ["f32", "f64"];
pub const CLASS_NAMES: [&str; 3] = ["L1", "LLC", "MEM"];

/// Which machine description produced a verdict.
#[derive(Clone, Copy, Debug)]
pub enum ModelSource {
    /// the detected host, with the measured streaming load bandwidth
    /// (GB/s) substituted for the detector's placeholder
    Detected { measured_bw_gbs: f64 },
    /// detection looked implausible; the nearest Table-1 preset stands in
    Preset(PresetId),
}

impl ModelSource {
    /// One-line provenance for the CLI.
    pub fn describe(&self) -> String {
        match self {
            ModelSource::Detected { measured_bw_gbs } => format!(
                "detected host, measured load bandwidth {measured_bw_gbs:.1} GB/s"
            ),
            ModelSource::Preset(id) => format!("nearest Table-1 preset fallback ({id:?})"),
        }
    }
}

/// The ECM governance verdict for one machine: predicted saturation cores
/// per (precision, size class) plus the provenance needed to explain it.
#[derive(Clone, Debug)]
pub struct EcmVerdict {
    /// the machine model the prediction was evaluated on
    pub machine: Machine,
    pub source: ModelSource,
    /// SIMD level of the Kahan kernel the model was built from
    pub simd: Simd,
    /// n_S per [precision][size class]; 0 means "does not saturate" (the
    /// class has no shared-bandwidth ceiling)
    pub sat_cores: [[u32; 3]; 2],
}

impl EcmVerdict {
    /// The caps the planner consumes: `usize::MAX` where the class does
    /// not saturate, n_S where it does. Monotone non-increasing in the
    /// size class within a precision — growing a working set can only
    /// move it toward the shared-bandwidth ceiling, never away from it.
    pub fn worker_caps(&self) -> [[usize; 3]; 2] {
        let mut caps = [[usize::MAX; 3]; 2];
        for (pi, row) in self.sat_cores.iter().enumerate() {
            for (ci, &n) in row.iter().enumerate() {
                if n > 0 {
                    caps[pi][ci] = n as usize;
                }
            }
        }
        caps
    }

    /// One cell of [`EcmVerdict::worker_caps`].
    pub fn cap(&self, prec_idx: usize, class_idx: usize) -> usize {
        let n = self.sat_cores[prec_idx][class_idx];
        if n == 0 { usize::MAX } else { n as usize }
    }
}

/// The widest SIMD level the host's Kahan kernels actually use.
pub fn best_host_simd() -> Simd {
    let s = host_simd();
    if s.avx512f {
        Simd::Avx512
    } else if s.avx2 {
        Simd::Avx
    } else if s.sse {
        Simd::Sse
    } else {
        Simd::Scalar
    }
}

/// ECM model for the Kahan dot at `prec`/`simd` on `machine`, multicore
/// Uncore behaviour (governance reasons about n > 1 cores).
pub fn model_for(machine: &Machine, simd: Simd, prec: Precision) -> EcmModel {
    build(machine, &generate(Variant::Kahan, simd, prec, 0), false)
}

/// Evaluate the governance verdict for one machine (pure; testable
/// against the Table-1 presets).
pub fn verdict_for(machine: &Machine, simd: Simd, source: ModelSource) -> EcmVerdict {
    let mut sat_cores = [[0u32; 3]; 2];
    for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
        let e = model_for(machine, simd, prec);
        // only the MEM class contends on a shared resource: L1/L2 are
        // per-core and the segmented L3 scales with active cores, so
        // their classes keep sat = 0 ("does not saturate")
        sat_cores[pi][2] = e.saturation_cores();
    }
    EcmVerdict { machine: machine.clone(), source, simd, sat_cores }
}

/// Bridge `machine::detect` into a governable machine model: take the
/// detected topology, pin the clock to the cached TSC calibration, and
/// substitute the measured streaming load bandwidth for the detector's
/// placeholder. When either figure is implausible the nearest Table-1
/// preset stands in, so governance always has *some* defensible model.
pub fn bridge_host() -> (Machine, ModelSource) {
    let host = detect_host_cached();
    let ghz = calibrate_tsc_ghz_cached();
    let bw = crate::bench::sweep::measure_load_bandwidth();
    if (0.5..7.0).contains(&ghz) && (0.5..1000.0).contains(&bw) {
        let mut m = host.clone();
        m.clock_ghz = ghz;
        m.memory.load_bw_gbs = bw;
        m.memory.peak_bw_gbs = m.memory.peak_bw_gbs.max(bw);
        (m, ModelSource::Detected { measured_bw_gbs: bw })
    } else {
        let id = nearest_preset(host);
        (preset(id), ModelSource::Preset(id))
    }
}

/// Process-wide cached host verdict. The bandwidth measurement behind
/// [`bridge_host`] streams ~64 MiB, so everything on a construction path
/// (engine setup, CLI) shares this one evaluation.
pub fn host_verdict() -> &'static EcmVerdict {
    static VERDICT: OnceLock<EcmVerdict> = OnceLock::new();
    VERDICT.get_or_init(|| {
        let (machine, source) = bridge_host();
        verdict_for(&machine, best_host_simd(), source)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::presets::ivb;

    /// The verdict reproduces the paper's §3 saturation points on IVB:
    /// AVX Kahan SP saturates at 4 cores, scalar Kahan at 11 (SP) / 6 (DP).
    #[test]
    fn verdict_matches_paper_saturation_on_ivb() {
        let m = ivb();
        let avx = verdict_for(&m, Simd::Avx, ModelSource::Preset(PresetId::Ivb));
        assert_eq!(avx.sat_cores[0][2], 4, "AVX SP n_S");
        let scalar = verdict_for(&m, Simd::Scalar, ModelSource::Preset(PresetId::Ivb));
        assert_eq!(scalar.sat_cores[0][2], 11, "scalar SP n_S");
        assert_eq!(scalar.sat_cores[1][2], 6, "scalar DP n_S");
    }

    /// Cap semantics: cache classes never cap, MEM caps at n_S, and the
    /// applied cap is monotone non-increasing in the size class.
    #[test]
    fn caps_only_bind_the_mem_class_and_are_monotone() {
        let v = verdict_for(&ivb(), Simd::Avx, ModelSource::Preset(PresetId::Ivb));
        let caps = v.worker_caps();
        for pi in 0..2 {
            assert_eq!(caps[pi][0], usize::MAX, "L1 class must not cap");
            assert_eq!(caps[pi][1], usize::MAX, "LLC class must not cap");
            assert!(caps[pi][2] >= 1);
            for w in caps[pi].windows(2) {
                assert!(w[1] <= w[0], "caps must be non-increasing in class");
            }
            for ci in 0..3 {
                assert_eq!(v.cap(pi, ci), caps[pi][ci]);
            }
        }
        assert_eq!(caps[0][2], 4);
    }

    /// The cached host verdict is computed once and is self-consistent
    /// with its own machine model.
    #[test]
    fn host_verdict_cached_and_plausible() {
        let a = host_verdict() as *const EcmVerdict;
        let b = host_verdict() as *const EcmVerdict;
        assert_eq!(a, b, "verdict must be evaluated once");
        let v = host_verdict();
        for pi in 0..2 {
            let n = v.sat_cores[pi][2];
            assert!(n >= 1, "a finite machine always has a MEM ceiling");
            assert!(n < 10_000, "implausible saturation point {n}");
        }
        assert!(v.machine.clock_ghz > 0.4 && v.machine.clock_ghz < 8.0);
    }
}
